"""Distributed MPAD: shard_map result parity with single-device (8 fake
devices in a subprocess so the main pytest process keeps 1 device)."""
import os
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.mpad import MPADConfig, fit_mpad
    from repro.core.distributed import fit_mpad_sharded, make_phi_dist
    from repro.core.fast_objective import phi_fast_value_and_grad
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P
    import functools

    x = jax.random.normal(jax.random.key(0), (256, 24))
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         devices=jax.devices()[:8])

    # 1. one-shot phi value/grad parity
    w = jax.random.normal(jax.random.key(1), (24,))
    w = w / jnp.linalg.norm(w)
    prev = jnp.zeros((3, 24)); mask = jnp.zeros((3,))
    v1, g1 = phi_fast_value_and_grad(w, x - x.mean(0), prev, mask,
                                     b=80.0, alpha=25.0)
    phi_d = make_phi_dist(("data", "model"), 256)
    f = shard_map(
        functools.partial(phi_d, b=80.0, alpha=25.0),
        mesh=mesh, in_specs=(P(), P(("data", "model"), None), P(), P()),
        out_specs=(P(), P()), check_rep=False)
    v2, g2 = jax.jit(f)(w, x - x.mean(0), prev, mask)
    np.testing.assert_allclose(float(v1), float(v2), rtol=1e-5)
    np.testing.assert_allclose(g1, g2, rtol=1e-3, atol=1e-5)
    print("PHI_PARITY_OK")

    # 2. end-to-end fit parity (float drift tolerated)
    cfg = MPADConfig(m=3, iters=16)
    r1 = fit_mpad(x, cfg)
    r2 = fit_mpad_sharded(x, cfg, mesh)
    err = float(jnp.max(jnp.abs(r1.matrix - r2.matrix)))
    assert err < 0.05, err
    print("FIT_PARITY_OK", err)
""")


def test_distributed_mpad_parity():
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=dict(os.environ), timeout=600)
    assert "PHI_PARITY_OK" in out.stdout, out.stderr[-3000:]
    assert "FIT_PARITY_OK" in out.stdout, out.stderr[-3000:]
