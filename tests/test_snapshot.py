"""Engine snapshot persistence: save/load parity for every index kind and
LUT dtype, streaming snapshots taken mid-delta, restore onto a mesh, and
the no-new-recompiles pin on restored engines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MPADConfig
from repro.search import StreamConfig, build_engine, load_engine

N, DIM, K = 600, 32, 10


def _data(seed=0, n=N, d=DIM):
    key = jax.random.key(seed)
    centers = jax.random.normal(key, (12, d)) * 2
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 12)
    return centers[lab] + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 2), (n, d))


def _queries(nq=16):
    x = _data()
    return x[:nq] + 0.02 * jax.random.normal(jax.random.key(9), (nq, DIM))


_SPECS = [
    "flat",
    "qpad8>rr64",
    "ivf12x5",
    "pq8x64",
    "pq8x64:i8",
    "qpad8>ivf12x5",
    "ivf12x5>pq8x64",
    "ivf12x5>pq8x64:i8",
    "qpad8>ivf12x5>pq8x64:i8",
]


def _engine(spec, **runtime):
    runtime.setdefault("fit_sample", 512)
    runtime.setdefault("mpad", MPADConfig(m=8, iters=16))
    return build_engine(_data(), spec, **runtime)


# --- save/load parity: all 4 kinds x f32/int8 LUTs ---------------------------

@pytest.mark.parametrize("spec", _SPECS)
def test_save_load_search_parity(spec, tmp_path):
    """load_engine(save(e)).search == e.search, pinned exactly."""
    eng = _engine(spec)
    q = _queries()
    d1, i1 = eng.search(q, K)
    eng.save(str(tmp_path))
    eng2 = load_engine(str(tmp_path))
    assert eng2.spec == eng.spec
    d2, i2 = eng2.search(q, K)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-6)


def test_restored_engine_compiles_no_new_program_shapes(tmp_path):
    """The restored engine reproduces shapes, dtypes, and the index kind's
    treedef exactly, so it holds ONE compiled program per (knobs, k,
    bucket) — same as a fresh build; repeated searches add nothing."""
    eng = _engine("qpad8>ivf12x5>pq8x64:i8")
    q = _queries()
    _, i1 = eng.search(q, K)
    assert eng.compile_count == 1
    eng.save(str(tmp_path))
    eng2 = load_engine(str(tmp_path))
    for _ in range(3):
        _, i2 = eng2.search(q, K)
    assert eng2.compile_count == 1, eng2.compile_count
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_runtime_overrides_on_load(tmp_path):
    eng = _engine("ivf12x5")
    eng.save(str(tmp_path))
    eng2 = load_engine(str(tmp_path), query_bucket=16)
    assert eng2.config.query_bucket == 16
    eng2.search(_queries(3), K)
    assert eng2.last_bucket == 4            # small-batch path intact


# --- streaming snapshots -----------------------------------------------------

@pytest.mark.stream
@pytest.mark.parametrize("spec", ["qpad8>rr128", "ivf12x5>pq8x64:i8>rr128"])
def test_streaming_snapshot_mid_delta(spec, tmp_path):
    """A snapshot taken mid-delta (un-compacted upserts + tombstones in
    flight) restores mid-delta: same results, same delta fill, and the
    write path keeps working — compaction after restore equals compaction
    without the round trip."""
    rng = np.random.RandomState(0)
    vecs = rng.randn(24, DIM).astype(np.float32)

    eng = _engine(spec, stream=StreamConfig(delta_capacity=64))
    eng.upsert(np.arange(N, N + 24), vecs)          # fresh delta rows
    eng.delete(np.arange(0, 30, 3))                 # base tombstones
    eng.upsert(np.array([5, 8]),
               rng.randn(2, DIM).astype(np.float32))   # base overwrites
    q = _queries()
    d1, i1 = eng.search(q, K)
    assert int(eng.store.delta_count) > 0          # genuinely mid-delta
    eng.save(str(tmp_path))
    eng2 = load_engine(str(tmp_path))
    assert int(eng2.store.delta_count) == int(eng.store.delta_count)
    assert eng2._delta_used == int(eng.store.delta_count)
    d2, i2 = eng2.search(q, K)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-6)
    # the write lifecycle continues from the snapshot
    for e in (eng, eng2):
        e.upsert(np.arange(N + 100, N + 110),
                 rng.randn(10, DIM).astype(np.float32))
        e.compact()
    _, i1c = eng.search(q, K)
    _, i2c = eng2.search(q, K)
    np.testing.assert_array_equal(np.asarray(i1c), np.asarray(i2c))


# --- restore onto a mesh -----------------------------------------------------

@pytest.mark.multidevice
def test_load_engine_onto_mesh(tmp_path):
    """``load_engine(dir, mesh=...)`` places the snapshot through
    ``restore_resharded`` and partitions it — identical ids to the
    single-device restore, no dense 2x left behind."""
    shards = min(2, jax.device_count())
    mesh = jax.make_mesh((shards,), ("data",),
                         devices=jax.devices()[:shards])
    eng = _engine("qpad8>ivf12x5>pq8x64")
    q = _queries()
    d1, i1 = eng.search(q, K)
    eng.save(str(tmp_path))
    eng2 = load_engine(str(tmp_path), mesh=mesh)
    assert eng2.sharded_state is not None
    assert eng2.state is None                      # dense copy donated
    d2, i2 = eng2.search(q, K)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-5)


# --- guard rails -------------------------------------------------------------

def test_save_after_donate_raises(tmp_path):
    mesh = jax.make_mesh((1,), ("data",))
    eng = _engine("ivf12x5")
    eng.shard(mesh, donate=True)
    with pytest.raises(RuntimeError, match="donate"):
        eng.save(str(tmp_path))


@pytest.mark.stream
def test_load_rejects_stream_override(tmp_path):
    """StreamConfig capacities are baked into the saved store's shapes —
    overriding stream= at load is refused instead of mis-provisioning."""
    eng = _engine("flat", stream=StreamConfig(delta_capacity=64))
    eng.save(str(tmp_path))
    with pytest.raises(ValueError, match="stream"):
        load_engine(str(tmp_path), stream=StreamConfig(delta_capacity=8))
    assert load_engine(str(tmp_path)).config.stream.delta_capacity == 64


def test_load_missing_snapshot_raises(tmp_path):
    with pytest.raises(FileNotFoundError, match="engine.json"):
        load_engine(str(tmp_path))


def test_snapshot_restores_reducer(tmp_path):
    eng = _engine("qpad8")
    eng.save(str(tmp_path))
    eng2 = load_engine(str(tmp_path))
    q = _queries(4)
    np.testing.assert_allclose(np.asarray(eng.reducer(q)),
                               np.asarray(eng2.reducer(q)), atol=1e-6)


def test_flat_alias_not_saved_twice(tmp_path):
    """flat with no Reduce stage scans the corpus itself: the snapshot
    stores the rows once and restore re-aliases the payload."""
    eng = build_engine(_data(), "flat")
    eng.save(str(tmp_path))
    eng2 = load_engine(str(tmp_path))
    assert eng2.state.index.payload is eng2.state.corpus
    q = _queries()
    np.testing.assert_array_equal(np.asarray(eng.search(q, K)[1]),
                                  np.asarray(eng2.search(q, K)[1]))
