"""Fault tolerance: checkpoint atomicity/retention, restart-replay
equivalence, elastic resharding across meshes (subprocess)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import (FailureInjector, latest_checkpoint,
                           restore_checkpoint, run_with_restarts,
                           save_checkpoint)


def _state():
    return {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "opt": {"step": jnp.int32(0), "m": jnp.zeros((2, 3))}}


def test_roundtrip(tmp_path):
    s = _state()
    save_checkpoint(str(tmp_path), 3, s)
    r = restore_checkpoint(latest_checkpoint(str(tmp_path)), s)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b), s, r)


def test_retention(tmp_path):
    s = _state()
    for i in range(6):
        save_checkpoint(str(tmp_path), i, s, keep=2)
    files = sorted(f for f in os.listdir(tmp_path) if f.endswith(".npz"))
    assert files == ["ckpt_0000000004.npz", "ckpt_0000000005.npz"]


def test_shape_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 0, _state())
    bad = {"w": jnp.zeros((3, 3)),
           "opt": {"step": jnp.int32(0), "m": jnp.zeros((2, 3))}}
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_checkpoint(latest_checkpoint(str(tmp_path)), bad)


def test_restart_replay_equivalence(tmp_path):
    """Training with injected failures == training without (deterministic
    data pipeline + checkpoint replay)."""

    def step_fn(state, step):
        g = jax.random.normal(jax.random.fold_in(jax.random.key(0), step),
                              (4,))
        return {"w": state["w"] - 0.1 * g}

    init = {"w": jnp.zeros(4)}
    clean = init
    for i in range(25):
        clean = step_fn(clean, i)
    faulty = run_with_restarts(
        step_fn, init, 25, str(tmp_path), ckpt_every=5,
        injector=FailureInjector(fail_at=[7, 13, 22]))
    np.testing.assert_allclose(clean["w"], faulty["w"], atol=1e-6)


def test_injector_exhausts_restarts(tmp_path):
    inj = FailureInjector(fail_at=list(range(100)))

    def step_fn(state, step):
        return state

    with pytest.raises(RuntimeError):
        run_with_restarts(step_fn, {"w": jnp.zeros(2)}, 10, str(tmp_path),
                          ckpt_every=100, injector=inj, max_restarts=3)


_RESHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.runtime import save_checkpoint, restore_resharded, \\
        latest_checkpoint
    state = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
    # save sharded on a 2x4 mesh
    mesh_a = jax.make_mesh((2, 4), ("data", "model"),
                           devices=jax.devices()[:8])
    xs = jax.device_put(state["w"], NamedSharding(mesh_a, P("data", "model")))
    save_checkpoint(sys.argv[1], 0, {"w": xs})
    # restore onto a 4x1 mesh (elastic: different device count/layout)
    mesh_b = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])
    sh = {"w": NamedSharding(mesh_b, P("data", None))}
    r = restore_resharded(latest_checkpoint(sys.argv[1]), state, sh)
    assert r["w"].sharding == sh["w"], r["w"].sharding
    np.testing.assert_allclose(np.asarray(r["w"]), np.asarray(state["w"]))
    print("RESHARD_OK")
""")


def test_elastic_reshard_subprocess(tmp_path):
    env = dict(os.environ)
    out = subprocess.run([sys.executable, "-c", _RESHARD_SCRIPT,
                          str(tmp_path)], capture_output=True, text=True,
                         env=env, cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "RESHARD_OK" in out.stdout, out.stderr[-2000:]
