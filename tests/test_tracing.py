"""Request-level tracing: staged deep traces, latency histograms,
Chrome-trace export, slow-query capture, shadow-exact recall.

The contracts pinned here:

* **exact decomposition** — the sampled deep trace re-runs a query batch
  through staged jitted programs with a block between stages, so the
  per-stage intervals are ordered, non-overlapping, and sum to the
  staged run's own end-to-end time (the acceptance bound: within 10%).
  ivfpq decomposes as project/probe/scan/rerank, other kinds as
  project/scan/rerank; the staged scan is the same math as the fused
  program (``ivfpq_scan_given_probe``).
* **zero interference** — tracing changes no results, and deep-trace
  stage programs live in jax's global jit cache: the engine's pinned
  ``compile_count`` never moves.
* **honest instruments** — histogram percentiles interpolate within the
  winning log-spaced bucket; the slow-query ring trims to capacity but
  keeps counting; Chrome-trace export is parseable JSON whose deep
  events tile the staged span; shadow recall scores against the LIVE
  rows (tombstone-aware on streaming engines).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.search import SearchEngine, ServeConfig, StreamConfig, TraceConfig
from repro.search import build_engine, deep_trace
from repro.search.tracing import LatencyHistogram, shadow_recall

pytestmark = pytest.mark.durability

N, DIM, K = 600, 32, 10


def _data(seed=0, n=N, d=DIM):
    key = jax.random.key(seed)
    centers = jax.random.normal(key, (12, d)) * 2
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 12)
    return centers[lab] + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 2), (n, d))


def _queries(n=8, seed=3):
    return jnp.asarray(np.asarray(_data(seed=seed, n=n), np.float32))


def _kw(eng):
    """The normalized knob dict ``search`` dispatches with."""
    cfg = eng.config
    probed = cfg.index in ("ivf", "ivfpq")
    coded = cfg.index in ("pq", "ivfpq")
    return dict(nprobe=cfg.nprobe if probed else 0, rerank=cfg.rerank,
                backend=cfg.pq_backend if coded else "jnp",
                interpret=cfg.pq_interpret if coded else True,
                lut_dtype=cfg.lut_dtype if coded else "f32",
                scan_cap=0, prefilter=0)


def test_deep_trace_ivfpq_decomposition():
    """The acceptance property: four named non-overlapping stages whose
    sum is within 10% of the staged run's measured end-to-end time."""
    eng = build_engine(_data(), "ivf12x4>pq8x64>rr40")
    q = _queries()
    eng.search(q, K)                     # warm the fused program
    out = deep_trace(eng, q, K, _kw(eng))
    assert out is not None
    names = [s for s, _ in out["stages"]]
    assert names == ["project", "probe", "scan", "rerank"]
    assert all(ms >= 0.0 for _, ms in out["stages"])
    total = sum(ms for _, ms in out["stages"])
    assert out["e2e_ms"] > 0.0
    assert abs(total - out["e2e_ms"]) <= 0.10 * out["e2e_ms"]


def test_deep_trace_generic_kind_and_guards():
    """Non-ivfpq kinds decompose as project/scan/rerank; engines without
    a read-only unsharded state (streaming) refuse instead of lying."""
    eng = build_engine(_data(), "ivf12x4")
    out = deep_trace(eng, _queries(), K, _kw(eng))
    assert [s for s, _ in out["stages"]] == ["project", "scan", "rerank"]
    total = sum(ms for _, ms in out["stages"])
    assert abs(total - out["e2e_ms"]) <= 0.10 * out["e2e_ms"]
    streaming = SearchEngine(_data(), ServeConfig(
        index="flat", stream=StreamConfig(delta_capacity=64)))
    assert deep_trace(streaming, _queries(), K, _kw(streaming)) is None


def test_tracing_changes_no_results_or_compiles():
    """Traced searches return bit-identical results, and the sampled
    deep traces never move the engine's pinned compile_count (the stage
    programs live in jax's global cache, not the engine's)."""
    plain = build_engine(_data(), "ivf12x4>pq8x64>rr40")
    traced = build_engine(_data(), "ivf12x4>pq8x64>rr40").tracing(
        deep_trace_every=1, recall_every=1, slow_query_ms=0.0)
    q = _queries()
    d0, i0 = plain.search(q, K)
    compiles = traced.compile_count
    for _ in range(3):
        d1, i1 = traced.search(q, K)
    assert traced.compile_count == compiles + 1    # the one fused program
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-6)
    assert traced.tracer.deep_traces == 3


def test_histogram_record_and_percentiles():
    h = LatencyHistogram()
    assert h.snapshot().percentile(50) == 0.0      # empty -> 0
    for _ in range(100):
        h.record(0.04)                             # below the first bound
    snap = h.snapshot()
    assert snap.count == 100
    assert snap.sum_ms == pytest.approx(4.0)
    assert 0.0 <= snap.percentile(50) <= 0.05
    h2 = LatencyHistogram()
    h2.record(1e9)                                 # beyond every bound
    over = h2.snapshot()
    assert over.counts[-1] == 1
    assert over.bounds_ms[-1] < over.percentile(50) <= over.bounds_ms[-1] * 2
    # interpolation: uniform mass in one bucket puts p25 below p75
    h3 = LatencyHistogram()
    for _ in range(10):
        h3.record(1.0)
    s3 = h3.snapshot()
    assert s3.percentile(25) < s3.percentile(75)


def test_traceconfig_validation():
    with pytest.raises(ValueError):
        TraceConfig(deep_trace_every=-1)
    with pytest.raises(ValueError):
        TraceConfig(recall_alpha=0.0)
    with pytest.raises(ValueError):
        TraceConfig(slow_query_ms=-0.5)


def test_chrome_trace_export(tmp_path):
    """Events export as parseable Chrome-trace JSON; the deep-trace
    stage events tile their search's span back-to-back; flush drains."""
    eng = build_engine(_data(), "ivf12x4>pq8x64>rr40").tracing(
        trace_dir=str(tmp_path / "traces"), deep_trace_every=1)
    q = _queries()
    for _ in range(3):
        eng.search(q, K)
    path = eng.flush_trace()
    assert path is not None
    with open(path) as f:
        doc = json.load(f)
    events = doc["traceEvents"]
    searches = [e for e in events if e["name"] == "search"]
    deep = [e for e in events if e["name"].startswith("deep.")]
    assert len(searches) == 3 and len(deep) == 3 * 4
    for e in events:
        assert e["ph"] == "X" and e["dur"] >= 0.0
    assert searches[0]["args"]["batch"] == 8
    stage_runs = [deep[i:i + 4] for i in range(0, len(deep), 4)]
    for run in stage_runs:                         # sequential tiling
        for a, b in zip(run, run[1:]):
            assert b["ts"] == pytest.approx(a["ts"] + a["dur"], abs=1e-6)
    # the buffer drained: a second flush writes an empty event list
    with open(eng.flush_trace()) as f:
        assert json.load(f)["traceEvents"] == []


def test_slow_query_ring_trims_but_keeps_counting():
    eng = build_engine(_data(), "flat").tracing(
        slow_query_ms=0.0, slow_query_capacity=4)
    q = _queries()
    for _ in range(7):
        eng.search(q, K)
    ring = eng.tracer.slow_query_log()
    assert len(ring) == 4                          # trimmed to capacity
    assert eng.tracer.slow_queries == 7            # counter keeps going
    assert [e["seq"] for e in ring] == [3, 4, 5, 6]   # oldest dropped
    assert ring[-1]["spec"] == "flat"
    # a threshold above any real latency captures nothing
    quiet = build_engine(_data(), "flat").tracing(slow_query_ms=1e9)
    quiet.search(q, K)
    assert quiet.tracer.slow_query_log() == []
    assert quiet.tracer.slow_queries == 0


def test_shadow_recall_is_tombstone_aware():
    """Streaming: an exact flat engine scores recall 1.0 both before and
    after deletes — the shadow truth is built from the LIVE rows, so
    tombstoned rows appear in neither the served ids nor the truth. (A
    tombstone-blind shadow would count deleted rows as truth and report
    a recall drop the serving path never had.)"""
    eng = SearchEngine(_data(), ServeConfig(
        index="flat", rerank=128,
        stream=StreamConfig(delta_capacity=64)))
    q = _queries()
    _, ids = eng.search(q, K)
    r, kk = shadow_recall(eng, q, q.shape[0], K, ids)
    assert kk == K and r == pytest.approx(1.0)
    victims = np.unique(np.asarray(ids)[:, :3].ravel()).astype(np.int32)
    eng.delete(victims)
    _, ids2 = eng.search(q, K)
    assert not np.isin(np.asarray(ids2), victims).any()
    r2, kk2 = shadow_recall(eng, q, q.shape[0], K, ids2)
    assert kk2 == K and r2 == pytest.approx(1.0)
    # read-only fallback: truth against state.corpus by row index
    ro = build_engine(_data(), "flat")
    _, ids3 = ro.search(q, K)
    r3, kk3 = shadow_recall(ro, q, q.shape[0], K, ids3)
    assert kk3 == K and r3 == pytest.approx(1.0)


def test_recall_gauge_feeds_maintenance_policy():
    """When a policy is configured, every shadow sample lands in
    MaintenancePolicy.observe_recall — same EMA the dashboards show."""
    from repro.search import PolicyConfig
    eng = SearchEngine(_data(), ServeConfig(
        index="flat", rerank=128,
        stream=StreamConfig(delta_capacity=64,
                            policy=PolicyConfig(recall_floor=0.5)))
        ).tracing(recall_every=1)
    q = _queries()
    for _ in range(3):
        eng.search(q, K)
    assert eng._policy.recall_samples == 3
    assert eng._policy.recall_ema == pytest.approx(
        eng.tracer.recall_ema)
    assert eng.metrics().recall.samples == 3


def test_trace_dir_property_attaches_and_updates(tmp_path):
    eng = build_engine(_data(), "flat")
    assert eng.trace_dir is None and eng.flush_trace() is None
    eng.trace_dir = str(tmp_path / "t")
    assert eng.tracer is not None and eng.tracer.active
    eng.search(_queries(), K)
    path = eng.flush_trace()
    with open(path) as f:
        assert len(json.load(f)["traceEvents"]) == 1
    # an all-off config is inert: the serve path takes no timestamp
    idle = build_engine(_data(), "flat").tracing(histograms=False)
    assert idle.tracer.active is False
    idle.search(_queries(), K)
    assert idle.tracer.queries == 0
