"""GNN + recsys + embedding substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data.graph import make_random_graph, sample_neighborhood_batch
from repro.models import gnn, recsys as rs
from repro.models.embedding import embedding_bag, embedding_lookup, hash_bucket


def test_embedding_bag_modes():
    table = jnp.arange(20, dtype=jnp.float32).reshape(10, 2)
    ids = jnp.array([[1, 3, -1], [0, -1, -1]])
    s = embedding_bag(table, ids, "sum")
    np.testing.assert_allclose(s[0], table[1] + table[3])
    np.testing.assert_allclose(s[1], table[0])
    m = embedding_bag(table, ids, "mean")
    np.testing.assert_allclose(m[0], (table[1] + table[3]) / 2)
    mx = embedding_bag(table, ids, "max")
    np.testing.assert_allclose(mx[0], jnp.maximum(table[1], table[3]))


def test_embedding_lookup_negative_ids_zero():
    table = jnp.ones((5, 3))
    out = embedding_lookup(table, jnp.array([-1, 2]))
    np.testing.assert_allclose(out[0], 0.0)
    np.testing.assert_allclose(out[1], 1.0)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 10**6), st.integers(2, 1000))
def test_hash_bucket_range(seed, buckets):
    ids = jax.random.randint(jax.random.key(seed), (50,), 0, 2**30)
    h = hash_bucket(ids, buckets)
    assert int(h.min()) >= 0 and int(h.max()) < buckets


def test_gin_permutation_invariance():
    """Sum aggregation is invariant to edge-list permutation."""
    cfg = gnn.GINConfig(name="g", n_layers=2, d_hidden=8, d_feat=4,
                        n_classes=2)
    p = gnn.gin_init_params(jax.random.key(0), cfg)
    feats = jax.random.normal(jax.random.key(1), (10, 4))
    src = jax.random.randint(jax.random.key(2), (30,), 0, 10)
    dst = jax.random.randint(jax.random.key(3), (30,), 0, 10)
    l1 = gnn.gin_full_forward(p, cfg, feats, src, dst)
    perm = jax.random.permutation(jax.random.key(4), 30)
    l2 = gnn.gin_full_forward(p, cfg, feats, src[perm], dst[perm])
    np.testing.assert_allclose(l1, l2, atol=1e-5)


def test_gin_edge_mask_drops_padding():
    cfg = gnn.GINConfig(name="g", n_layers=2, d_hidden=8, d_feat=4,
                        n_classes=2)
    p = gnn.gin_init_params(jax.random.key(0), cfg)
    feats = jax.random.normal(jax.random.key(1), (10, 4))
    src = jnp.array([0, 1, 2])
    dst = jnp.array([3, 4, 5])
    l1 = gnn.gin_full_forward(p, cfg, feats, src, dst)
    srcp = jnp.concatenate([src, jnp.array([7, 8])])
    dstp = jnp.concatenate([dst, jnp.array([0, 1])])
    mask = jnp.array([1.0, 1, 1, 0, 0])
    l2 = gnn.gin_full_forward(p, cfg, feats, srcp, dstp, mask)
    np.testing.assert_allclose(l1, l2, atol=1e-5)


def test_neighbor_sampler_shapes():
    feats, src, dst, labels = make_random_graph(0, 100, 400, 6, 4)
    b = sample_neighborhood_batch(1, feats, src, dst, labels, 8, (3, 2))
    assert b["feat_l0"].shape == (8, 6)
    assert b["feat_l1"].shape == (8, 3, 6)
    assert b["feat_l2"].shape == (8, 3, 2, 6)
    assert b["labels"].shape == (8,)


def test_sasrec_padding_masked():
    cfg = rs.SASRecConfig(name="s", n_items=50, seq_len=8)
    p = rs.sasrec_init(jax.random.key(0), cfg)
    seq = jnp.array([[1, 2, 3, -1, -1, -1, -1, -1]])
    h = rs.sasrec_forward(p, cfg, seq)
    np.testing.assert_allclose(h[0, 3:], 0.0, atol=1e-6)  # padded zeroed


def test_sasrec_blocked_topk_matches_dense():
    cfg = rs.SASRecConfig(name="s", n_items=64, seq_len=8)
    p = rs.sasrec_init(jax.random.key(0), cfg)
    seq = jax.random.randint(jax.random.key(1), (3, 8), 0, 64)
    s1, i1 = rs.sasrec_serve_topk(p, cfg, seq, k=5, item_chunk=16)
    h = rs.sasrec_forward(p, cfg, seq)[:, -1]
    dense = h @ p["item_emb"].T
    s2, i2 = jax.lax.top_k(dense, 5)
    np.testing.assert_allclose(s1, s2, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_dien_shared_gru_matches_forward():
    cfg = rs.DIENConfig(name="d", n_items=40, n_cats=5, seq_len=6)
    p = rs.dien_init(jax.random.key(0), cfg)
    hist_i = jax.random.randint(jax.random.key(1), (1, 6), 0, 40)
    hist_c = jax.random.randint(jax.random.key(2), (1, 6), 0, 5)
    cands = jnp.arange(8)
    ccats = jnp.zeros(8, jnp.int32)
    bulk = rs.dien_score(p, cfg, {"hist_items": hist_i, "hist_cats": hist_c,
                                  "cand_items": cands, "cand_cats": ccats})
    for j in [0, 5]:
        one, _ = rs.dien_forward(p, cfg, {
            "hist_items": hist_i, "hist_cats": hist_c,
            "target_item": cands[j:j + 1], "target_cat": ccats[j:j + 1]})
        np.testing.assert_allclose(float(bulk[j]), float(one[0]), rtol=1e-4,
                                   atol=1e-5)


def test_autoint_candidate_scoring_consistent():
    cfg = rs.AutoIntConfig(name="a", n_fields=5, vocab_per_field=30)
    p = rs.autoint_init(jax.random.key(0), cfg)
    user = jax.random.randint(jax.random.key(1), (4,), 0, 30)
    cands = jnp.arange(8)
    bulk = rs.autoint_score_candidates(p, cfg, user, cands, chunk=4)
    rows = jnp.concatenate([cands[:, None],
                            jnp.broadcast_to(user[None], (8, 4))], axis=1)
    direct = rs.autoint_forward(p, cfg, rows)
    np.testing.assert_allclose(bulk, direct, atol=1e-5)


def test_twotower_normalized_and_retrieval():
    cfg = rs.TwoTowerConfig(name="t", n_users=50, n_items=40, n_negatives=8)
    p = rs.twotower_init(jax.random.key(0), cfg)
    u = rs.twotower_user(p, cfg, jnp.arange(5),
                         jnp.zeros((5, cfg.n_user_feats), jnp.int32))
    np.testing.assert_allclose(jnp.linalg.norm(u, axis=1), 1.0, rtol=1e-4)
    cand = rs.twotower_item(p, cfg, jnp.arange(40))
    s, ids = rs.twotower_retrieve(
        p, cfg, {"user_ids": jnp.arange(1),
                 "hist_ids": jnp.zeros((1, cfg.n_user_feats), jnp.int32),
                 "cand_emb": cand}, k=5)
    # full-dim exact: must equal brute force
    brute = jnp.argsort(-(u[0] @ cand.T) if False else -(rs.twotower_user(
        p, cfg, jnp.arange(1), jnp.zeros((1, cfg.n_user_feats),
                                         jnp.int32))[0] @ cand.T))[:5]
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(brute))
