"""Reducer & index zoo conformance: every registered kind rides the stack.

The zoo's contract is that registering a reducer kind (``ReducerOps``) or
an index kind (``IndexOps``) buys the full serving stack for free. This
suite pins that over the **cross product** of registered reducer kinds
(``qpad`` | ``pca`` | ``mlp``) x index layouts (``flat`` | ``ivf`` |
``pq`` | ``opq`` | ``ivfpq``):

* **grammar** — every combination parses and ``format_spec`` round-trips;
  unknown kinds / malformed Reduce tokens raise actionable errors naming
  the registered kinds;
* **build/search** — engine search returns the same ids as a from-scratch
  oracle rebuild over the same frozen quantizers (``rebuild_state``);
* **snapshot** — save/load round-trips to identical ids, including the
  pre-zoo back-compat path (metadata without a ``"reducer"`` key);
* **sharded** — 1/2/8-device ``sharded_search_fn`` parity (the >1-shard
  cases need ``XLA_FLAGS=--xla_force_host_platform_device_count=8``);
* **streaming** — interleaved upsert/delete then ``compact()`` equals the
  from-scratch rebuild over the survivors.

New kinds registered via ``register_reducer`` / ``register_index`` are
picked up automatically (the parameterization reads the registries).
"""
import dataclasses
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.engine import shard_engine
from repro.search import (REDUCER_KINDS, SearchEngine, StreamConfig,
                          build_engine, format_spec, load_engine,
                          make_mutable, parse_spec, rebuild_state,
                          save_engine, search_fn, sharded_search_fn)

N, DIM, M, K = 600, 32, 8, 10

# index layouts as spec fragments (opq composes with a reducer but not
# with a coarse stage — the rotation is global; see IndexSpec validation)
_INDEX_FRAGS = {
    "flat": "flat",
    "ivf": "ivf12x5",
    "pq": "pq8x64",
    "opq": "opq8x64",
    "ivfpq": "ivf12x5>pq8x64",
}
_COMBOS = [(red, idx) for red in REDUCER_KINDS for idx in _INDEX_FRAGS]


def _spec(red, index):
    return f"{red}{M}>{_INDEX_FRAGS[index]}"


def _data(seed=0, n=N, d=DIM):
    key = jax.random.key(seed)
    centers = jax.random.normal(key, (12, d)) * 2
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 12)
    return centers[lab] + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 2), (n, d))


def _queries(nq=16, d=DIM):
    x = _data(d=d)
    return x[:nq] + 0.02 * jax.random.normal(jax.random.key(9), (nq, d))


_ENGINES = {}


def _engine(red, index):
    """One build per combo (reducer fit + index train are the slow part)."""
    if (red, index) not in _ENGINES:
        _ENGINES[(red, index)] = build_engine(
            _data(), _spec(red, index), fit_sample=512, seed=0)
    return _ENGINES[(red, index)]


# --- grammar: the cross product parses, errors are actionable ----------------

@pytest.mark.parametrize("red,index", _COMBOS)
def test_spec_round_trips(red, index):
    spec = parse_spec(_spec(red, index))
    assert spec.reduce.kind == red and spec.reduce.m == M
    assert spec.kind == index
    assert parse_spec(format_spec(spec)) == spec


def test_unknown_reducer_kind_names_registered_kinds():
    with pytest.raises(ValueError, match="registered reducer kinds"):
        parse_spec("zap16>flat")
    with pytest.raises(ValueError) as e:
        parse_spec("zap16>flat")
    for kind in REDUCER_KINDS:
        assert kind in str(e.value)


def test_malformed_flat_tokens_error():
    with pytest.raises(ValueError, match="duplicate 'flat'"):
        parse_spec("flat>flat")
    with pytest.raises(ValueError, match="mixes 'flat'"):
        parse_spec("ivf12x5>flat")
    with pytest.raises(ValueError, match="mixes 'flat'"):
        parse_spec("flat>pq8x64")
    with pytest.raises(ValueError, match="out of pipeline order"):
        parse_spec("rr40>flat")


def test_opq_under_coarse_is_rejected():
    with pytest.raises(ValueError, match="opq"):
        parse_spec("qpad8>ivf12x5>opq8x64")


# --- build/search: engine == from-scratch oracle rebuild ---------------------

@pytest.mark.parametrize("red,index", _COMBOS)
def test_search_matches_rebuild_oracle(red, index):
    """Engine search over the build-time index returns the same ids as an
    oracle that re-encodes the corpus from scratch under the same frozen
    quantizers — build and rebuild agree for every combo."""
    eng = _engine(red, index)
    _, frozen = make_mutable(eng.state, StreamConfig(delta_capacity=64))
    oracle = rebuild_state(frozen, _data())
    q = _queries()
    d1, i1 = eng.search(q, K)
    d2, i2 = search_fn(oracle, q, K, nprobe=5, rerank=64, backend="jnp")
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-5)


# --- snapshots: round-trip + pre-zoo back-compat -----------------------------

@pytest.mark.parametrize("red,index", _COMBOS)
def test_snapshot_round_trip(red, index):
    eng = _engine(red, index)
    q = _queries()
    d1, i1 = eng.search(q, K)
    with tempfile.TemporaryDirectory() as td:
        save_engine(eng, td)
        with open(os.path.join(td, "engine.json")) as f:
            assert json.load(f)["reducer"] == red
        eng2 = load_engine(td)
    d2, i2 = eng2.search(q, K)
    assert eng2.reducer.kind == red
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-6)


def test_pre_zoo_snapshot_without_reducer_key_loads_as_qpad():
    """Back-compat pin: snapshots written before the zoo carry only
    ``has_proj`` — they must load as ``qpad`` with identical ids."""
    eng = _engine("qpad", "ivfpq")
    q = _queries()
    d1, i1 = eng.search(q, K)
    with tempfile.TemporaryDirectory() as td:
        save_engine(eng, td)
        meta_path = os.path.join(td, "engine.json")
        with open(meta_path) as f:
            meta = json.load(f)
        del meta["reducer"]                      # what old snapshots look like
        with open(meta_path, "w") as f:
            json.dump(meta, f)
        eng2 = load_engine(td)
    assert eng2.reducer.kind == "qpad"
    d2, i2 = eng2.search(q, K)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


# --- sharded serving: the distributed merge is invisible ---------------------

@pytest.mark.multidevice
@pytest.mark.parametrize("shards", (1, 2, 8))
@pytest.mark.parametrize("red,index", _COMBOS)
def test_sharded_parity(red, index, shards):
    if jax.device_count() < shards:
        pytest.skip(f"needs {shards} devices (run under XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={shards})")
    mesh = jax.make_mesh((shards,), ("data",),
                         devices=jax.devices()[:shards])
    eng = _engine(red, index)
    q = _queries()
    d1, i1 = search_fn(eng.state, q, K, nprobe=5, rerank=64, backend="jnp")
    sstate = shard_engine(eng.state, mesh)
    d2, i2 = sharded_search_fn(sstate, q, K, mesh=mesh, axis="data",
                               nprobe=5, rerank=64, backend="jnp")
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-5)


# --- streaming: interleaved writes + compact == rebuild ----------------------

@pytest.mark.stream
@pytest.mark.parametrize("red,index", _COMBOS)
def test_stream_compact_equals_rebuild(red, index):
    eng = build_engine(_data(), _spec(red, index), fit_sample=512, seed=0,
                       stream=StreamConfig(delta_capacity=64))
    rng = np.random.RandomState(3)
    alive = {i: np.asarray(_data()[i]) for i in range(N)}
    next_id = N
    for _ in range(6):
        if rng.rand() < 0.6:
            ids = np.arange(next_id, next_id + 8)
            vecs = rng.randn(8, DIM).astype(np.float32)
            next_id += 8
            for i, v in zip(ids, vecs):
                alive[int(i)] = v
            eng.upsert(ids, vecs)
        else:
            drop = [int(i) for i in rng.choice(list(alive), 5, replace=False)]
            for i in drop:
                del alive[i]
            eng.delete(np.array(drop))
    eng.compact()
    assert int(eng.store.delta_count) == 0
    surv_ids = np.array(sorted(alive))
    surv = jnp.asarray(np.stack([alive[i] for i in surv_ids]))
    oracle = rebuild_state(eng.frozen, surv)
    q = _queries()
    d_r, i_r = search_fn(oracle, q, K, nprobe=5, rerank=64, backend="jnp")
    ext_r = surv_ids[np.asarray(i_r)]
    d_s, i_s = eng.search(q, K)
    np.testing.assert_array_equal(np.sort(np.asarray(i_s), axis=1),
                                  np.sort(ext_r, axis=1))
    np.testing.assert_allclose(np.sort(np.asarray(d_s), axis=1),
                               np.sort(np.asarray(d_r), axis=1), atol=1e-4)


# --- the acceptance specs, verbatim ------------------------------------------

@pytest.mark.parametrize("spec", ["pca32>ivf64x8>pq8x256:i8", "mlp32>flat",
                                  "qpad32>opq8x256:i8"])
def test_acceptance_specs_end_to_end(spec):
    """The issue's named specs parse, build, search, and snapshot
    round-trip with pinned ids (64-dim corpus so m=32 reduces)."""
    corpus = _data(n=800, d=64)
    eng = build_engine(corpus, spec, fit_sample=512, seed=0)
    q = _queries(d=64)
    d1, i1 = eng.search(q, K)
    assert i1.shape == (q.shape[0], K)
    with tempfile.TemporaryDirectory() as td:
        save_engine(eng, td)
        eng2 = load_engine(td)
    _, i2 = eng2.search(q, K)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
