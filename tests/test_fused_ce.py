"""Fused cross-entropy Pallas kernel vs materialized-logits oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.fused_ce import ce_ref, fused_ce, fused_ce_fwd


@pytest.mark.parametrize("t,d,v,vocab,bt,bv", [
    (32, 16, 64, None, 16, 16),
    (64, 32, 256, 200, 32, 64),       # padded vocab masked
    (48, 8, 96, None, 16, 32),
    (128, 64, 512, 500, 64, 128),
])
def test_fused_ce_matches_ref(t, d, v, vocab, bt, bv):
    key = jax.random.key(0)
    h = jax.random.normal(key, (t, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (d, v)) * 0.1
    voc = vocab or v
    labels = jax.random.randint(jax.random.fold_in(key, 2), (t,), 0, voc)
    out = fused_ce_fwd(h, w, labels, vocab=vocab, block_t=bt, block_v=bv)
    ref = ce_ref(h, w, labels, vocab=vocab)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_fused_ce_grads_match_autodiff():
    key = jax.random.key(1)
    t, d, v = 32, 16, 64
    h = jax.random.normal(key, (t, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (d, v)) * 0.1
    labels = jax.random.randint(jax.random.fold_in(key, 2), (t,), 0, v)
    gk = jax.grad(lambda h_, w_: jnp.mean(fused_ce(h_, w_, labels)),
                  argnums=(0, 1))(h, w)
    gr = jax.grad(lambda h_, w_: jnp.mean(ce_ref(h_, w_, labels)),
                  argnums=(0, 1))(h, w)
    np.testing.assert_allclose(gk[0], gr[0], atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(gk[1], gr[1], atol=1e-5, rtol=1e-4)


def test_fused_ce_bf16_inputs():
    key = jax.random.key(2)
    h = jax.random.normal(key, (32, 16)).astype(jnp.bfloat16)
    w = (jax.random.normal(jax.random.fold_in(key, 1), (16, 64)) * 0.1
         ).astype(jnp.bfloat16)
    labels = jax.random.randint(jax.random.fold_in(key, 2), (32,), 0, 64)
    out = fused_ce_fwd(h, w, labels, block_t=16, block_v=16)
    ref = ce_ref(h.astype(jnp.float32), w.astype(jnp.float32), labels)
    np.testing.assert_allclose(out, ref, atol=5e-2, rtol=2e-2)


@settings(max_examples=10, deadline=None)
@given(st.integers(8, 64), st.integers(4, 24), st.integers(16, 128),
       st.integers(0, 10**6))
def test_fused_ce_property(t, d, v, seed):
    t, v = (t // 8) * 8, (v // 16) * 16
    h = jax.random.normal(jax.random.key(seed), (t, d))
    w = jax.random.normal(jax.random.key(seed + 1), (d, v)) * 0.2
    labels = jax.random.randint(jax.random.key(seed + 2), (t,), 0, v)
    out = fused_ce_fwd(h, w, labels, block_t=8, block_v=16)
    ref = ce_ref(h, w, labels)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
    assert bool(jnp.all(out > -1e-5))          # CE is non-negative-ish
