"""MPAD trainer behaviour tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MPADConfig, fit_mpad, transform


def _clustered(n=300, d=24, seed=0):
    key = jax.random.key(seed)
    centers = jax.random.normal(key, (8, d)) * 2.0
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 8)
    return centers[lab] + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 2), (n, d))


def test_objective_improves():
    x = _clustered()
    res = fit_mpad(x, MPADConfig(m=4, iters=40))
    tr = res.objective_trace
    assert float(tr[0, -1]) > float(tr[0, 0])          # dir 0 improved


def test_transform_shapes_and_call():
    x = _clustered()
    res = fit_mpad(x, MPADConfig(m=6, iters=8))
    assert res.matrix.shape == (6, 24)
    assert transform(res, x).shape == (300, 6)
    assert res(x[:5]).shape == (5, 6)
    np.testing.assert_allclose(jnp.linalg.norm(res.matrix, axis=1),
                               np.ones(6), rtol=1e-4)


def test_high_alpha_near_orthogonal():
    """alpha=10000 'essentially enforces orthogonality' (paper Sec 4.1)."""
    x = _clustered(seed=3)
    res = fit_mpad(x, MPADConfig(m=4, alpha=10000.0, iters=60))
    gram = res.matrix @ res.matrix.T
    off = gram - jnp.diag(jnp.diag(gram))
    assert float(jnp.max(jnp.abs(off))) < 0.1


def test_backends_agree():
    x = _clustered(n=200, seed=5)
    cfg = dict(m=2, iters=10, seed=11)
    r_fast = fit_mpad(x, MPADConfig(backend="fast", **cfg))
    r_exact = fit_mpad(x, MPADConfig(backend="exact", **cfg))
    r_kernel = fit_mpad(x, MPADConfig(backend="kernel", **cfg))
    np.testing.assert_allclose(r_fast.matrix, r_exact.matrix, atol=2e-3)
    np.testing.assert_allclose(r_fast.matrix, r_kernel.matrix, atol=2e-3)


def test_stochastic_backend_runs():
    x = _clustered(n=400, seed=7)
    res = fit_mpad(x, MPADConfig(m=2, iters=12, batch_size=128))
    assert bool(jnp.all(jnp.isfinite(res.matrix)))


def test_validation():
    x = _clustered()
    with pytest.raises(ValueError):
        fit_mpad(x, MPADConfig(m=100))                 # m > n
    with pytest.raises(ValueError):
        MPADConfig(m=2, b=0.0)
    with pytest.raises(ValueError):
        MPADConfig(m=2, backend="nope")


def test_centering():
    x = _clustered() + 100.0
    res = fit_mpad(x, MPADConfig(m=2, iters=8, center=True))
    y = transform(res, x)
    assert float(jnp.abs(jnp.mean(y))) < 5.0           # offset removed
