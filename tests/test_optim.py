"""Optimizer + gradient-compression tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (AdamWConfig, adamw_update, compress_int8,
                         decompress_int8, ef_compress_update,
                         init_compression_state, init_opt_state,
                         make_train_step)


def _quadratic_problem():
    target = jnp.array([1.0, -2.0, 3.0])

    def loss(params, batch):
        return jnp.sum((params["w"] - target) ** 2)

    return {"w": jnp.zeros(3)}, loss, target


def test_adamw_converges_quadratic():
    params, loss, target = _quadratic_problem()
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=500, clip_norm=None)
    step = jax.jit(make_train_step(loss, cfg))
    opt = init_opt_state(params)
    for _ in range(300):
        l, params, opt = step(params, opt, None)
    np.testing.assert_allclose(params["w"], target, atol=0.05)


def test_clipping_bounds_update():
    params = {"w": jnp.zeros(4)}
    grads = {"w": jnp.full(4, 1e6)}
    cfg = AdamWConfig(lr=0.1, clip_norm=1.0, warmup_steps=0, weight_decay=0.0)
    new, opt = adamw_update(grads, init_opt_state(params), params, cfg)
    assert float(jnp.max(jnp.abs(new["w"]))) < 1.0


def test_warmup_schedule():
    from repro.optim.adamw import _schedule
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(_schedule(cfg, jnp.int32(5))) == 0.5
    assert float(_schedule(cfg, jnp.int32(10))) == 1.0
    assert float(_schedule(cfg, jnp.int32(100))) <= cfg.min_lr_frac + 1e-6


def test_int8_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.key(0), (1000,)) * 10
    q, s = compress_int8(x)
    err = jnp.abs(decompress_int8(q, s) - x)
    assert float(jnp.max(err)) <= float(s) * 0.51 + 1e-6


def test_error_feedback_accumulates():
    """EF: sum of transported grads over steps ~= sum of true grads."""
    params = {"w": jnp.zeros(8)}
    state = init_compression_state(params)
    true_sum = jnp.zeros(8)
    sent_sum = jnp.zeros(8)
    for i in range(50):
        g = {"w": jax.random.normal(jax.random.key(i), (8,)) * 0.01}
        dec, state = ef_compress_update(g, state)
        true_sum = true_sum + g["w"]
        sent_sum = sent_sum + dec["w"]
    resid = state.error["w"]
    np.testing.assert_allclose(sent_sum + resid, true_sum, atol=1e-4)


def test_compressed_training_converges():
    params, loss, target = _quadratic_problem()
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      clip_norm=None)
    opt = init_opt_state(params)
    cstate = init_compression_state(params)
    for _ in range(300):
        g = jax.grad(lambda p: loss(p, None))(params)
        g, cstate = ef_compress_update(g, cstate)
        params, opt = adamw_update(g, opt, params, cfg)
    np.testing.assert_allclose(params["w"], target, atol=0.1)
