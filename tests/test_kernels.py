"""Per-kernel validation vs the pure-jnp oracles: shape/dtype sweeps +
hypothesis property tests (interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.objective import mu_b_exact_value_and_grad
from repro.kernels.mpad_pairwise import (mu_kernel_value_and_grad,
                                         pairwise_stats_pallas,
                                         pairwise_stats_ref)
from repro.kernels.knn_topk import knn_ref, knn_topk_pallas


# ------------------------------------------------------ mpad_pairwise

@pytest.mark.parametrize("n,block", [(64, 64), (96, 32), (257, 64),
                                     (512, 128), (100, 256)])
def test_pairwise_stats_shapes(n, block):
    p = jax.random.normal(jax.random.key(n), (n,))
    tau = jnp.float32(0.5)
    c_r, s_r, co_r = pairwise_stats_ref(p, tau)
    c_k, s_k, co_k = pairwise_stats_pallas(p, tau, block_i=block,
                                           block_j=block)
    assert int(c_r) == int(c_k)
    np.testing.assert_allclose(float(s_r), float(s_k), rtol=1e-4)
    np.testing.assert_allclose(co_r, co_k, atol=1e-5)


@pytest.mark.parametrize("scale", [1e-3, 1.0, 1e3])
def test_pairwise_stats_scales(scale):
    """Scale invariance of the counting rule (f32 dynamic range sweep)."""
    p = jax.random.normal(jax.random.key(1), (128,)) * scale
    tau = jnp.float32(0.3 * scale)
    c_r, s_r, co_r = pairwise_stats_ref(p, tau)
    c_k, s_k, co_k = pairwise_stats_pallas(p, tau, block_i=64, block_j=64)
    assert int(c_r) == int(c_k)
    np.testing.assert_allclose(co_r, co_k, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(10, 150), st.floats(0.01, 3.0), st.integers(0, 10**6))
def test_pairwise_stats_property(n, tau, seed):
    p = jax.random.normal(jax.random.key(seed), (n,))
    c_r, s_r, co_r = pairwise_stats_ref(p, jnp.float32(tau))
    c_k, s_k, co_k = pairwise_stats_pallas(p, jnp.float32(tau),
                                           block_i=64, block_j=64)
    assert int(c_r) == int(c_k)
    np.testing.assert_allclose(float(s_r), float(s_k), rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(co_r, co_k, atol=1e-5)


@pytest.mark.parametrize("b", [20.0, 80.0])
def test_kernel_mu_matches_exact_oracle(b):
    x = jax.random.normal(jax.random.key(2), (200, 12))
    w = jax.random.normal(jax.random.key(3), (12,))
    w = w / jnp.linalg.norm(w)
    ve, ge = mu_b_exact_value_and_grad(w, x, b=b)
    vk, gk = mu_kernel_value_and_grad(w, x, b=b, block=64)
    np.testing.assert_allclose(float(ve), float(vk), rtol=1e-5)
    np.testing.assert_allclose(ge, gk, rtol=1e-3, atol=1e-5)


# ----------------------------------------------------------- knn_topk

@pytest.mark.parametrize("q,n,d,k,bq,bn", [
    (32, 200, 8, 5, 32, 64), (130, 1000, 32, 10, 64, 128),
    (64, 64, 4, 16, 64, 64), (7, 333, 17, 3, 32, 128)])
def test_knn_topk_shapes(q, n, d, k, bq, bn):
    qv = jax.random.normal(jax.random.key(q), (q, d))
    xv = jax.random.normal(jax.random.key(n), (n, d))
    d_k, i_k = knn_topk_pallas(qv, xv, k, block_q=bq, block_n=bn)
    d_r, i_r = knn_ref(qv, xv, k)
    np.testing.assert_array_equal(np.sort(np.asarray(i_k), 1),
                                  np.sort(np.asarray(i_r), 1))
    np.testing.assert_allclose(d_k, d_r, rtol=1e-4, atol=1e-4)


def test_knn_topk_bf16_inputs():
    qv = jax.random.normal(jax.random.key(0), (32, 16)).astype(jnp.bfloat16)
    xv = jax.random.normal(jax.random.key(1), (128, 16)).astype(jnp.bfloat16)
    d_k, i_k = knn_topk_pallas(qv, xv, 5, block_q=32, block_n=64)
    d_r, i_r = knn_ref(qv.astype(jnp.float32), xv.astype(jnp.float32), 5)
    # bf16 distance ties can permute ids; require >=80% id agreement
    agree = (np.sort(np.asarray(i_k), 1) == np.sort(np.asarray(i_r), 1)).mean()
    assert agree > 0.8


@settings(max_examples=10, deadline=None)
@given(st.integers(5, 60), st.integers(20, 200), st.integers(2, 12),
       st.integers(1, 8), st.integers(0, 10**6))
def test_knn_topk_property(q, n, d, k, seed):
    k = min(k, n)
    qv = jax.random.normal(jax.random.key(seed), (q, d))
    xv = jax.random.normal(jax.random.key(seed + 1), (n, d))
    d_k, i_k = knn_topk_pallas(qv, xv, k, block_q=32, block_n=64)
    d_r, i_r = knn_ref(qv, xv, k)
    np.testing.assert_array_equal(np.sort(np.asarray(i_k), 1),
                                  np.sort(np.asarray(i_r), 1))
    # distances ascending
    assert bool(jnp.all(jnp.diff(d_k, axis=1) >= -1e-6))
