"""One-program serving tests: EngineState/search_fn purity, the per-engine
compile cache (bucketed batches must NOT recompile), and the dedup'd masked
re-rank."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.search import (EngineState, SearchEngine, ServeConfig,
                          exact_rerank, ivfpq_search, knn_search, search_fn)
from repro.search.knn import recall_at_k


def _data(seed=0, n=600, d=32):
    key = jax.random.key(seed)
    centers = jax.random.normal(key, (12, d)) * 2
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 12)
    return centers[lab] + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 2), (n, d))


def _engine(**kw):
    cfg = dict(index="ivfpq", nlist=16, nprobe=8, pq_subspaces=8,
               pq_centroids=64, rerank=64)
    cfg.update(kw)
    return SearchEngine(_data(), ServeConfig(**cfg))


# --- compile-count regression ------------------------------------------------

def test_single_compilation_across_ragged_batches():
    """Batches of sizes {9, 33, 64} must share ONE compiled program per
    (index, k): the engine pads them all into the default 64-query bucket.
    (Batches <= ServeConfig.small_batch take their own small bucket — see
    the latency-cliff tests below — so the shared-bucket regime starts
    above it.)"""
    q = _data(seed=3, n=64)
    # warm the global jit caches of the tiny eager glue ops (pad, slice) with
    # a sacrificial engine, so the monitoring hook below sees only THIS
    # engine's program compiles
    warm = _engine()
    for nq in (9, 33, 64):
        warm.search(q[:nq], 10)
    eng = _engine()
    compiles = []
    active = [True]                  # listeners can't be unregistered; gate
    #                                  it off after the test so it can't
    #                                  miscount for the rest of the session

    def _listener(name, *a, **kw):
        if active[0] and name == "/jax/core/compile/backend_compile_duration":
            compiles.append(name)

    jax.monitoring.register_event_duration_secs_listener(_listener)
    try:
        for nq in (9, 33, 64):
            d, ids = eng.search(q[:nq], 10)
            assert d.shape == (nq, 10) and ids.shape == (nq, 10)
        assert eng.compile_count == 1, eng.compile_count
        # the monitoring hook agrees: exactly one backend compile was
        # triggered by this engine's searches
        assert len(compiles) == 1, compiles
        # a different k is a different program
        eng.search(q[:14], 5)
        assert eng.compile_count == 2
    finally:
        active[0] = False


def test_bucket_rounds_up_in_powers_of_two():
    # small_batch=0 disables the latency-cliff floor path, isolating the
    # pure bucket-rounding behavior
    eng = _engine(query_bucket=8, small_batch=0)
    q = _data(seed=3, n=40)
    for nq in (1, 5, 8):
        eng.search(q[:nq], 10)
    assert eng.compile_count == 1            # all inside the 8-bucket
    eng.search(q[:9], 10)                    # spills into the 16-bucket
    assert eng.compile_count == 2
    eng.search(q[:16], 10)
    assert eng.compile_count == 2


# --- small-batch latency cliff (compute-proportional floor path) -------------

def test_small_batch_takes_compute_proportional_bucket():
    """Batches <= small_batch must NOT pad to the 64-query bucket: the
    padded program shape (``last_bucket``) is the latency pin — a 1-query
    batch runs a 1-wide scan, not a 64-wide one."""
    eng = _engine()                          # default query_bucket=64,
    q = _data(seed=4, n=70)                  # default small_batch=8
    for nq, want in ((1, 1), (3, 4), (8, 8), (9, 64), (64, 64), (70, 128)):
        eng.search(q[:nq], 10)
        assert eng.last_bucket == want, (nq, eng.last_bucket)
    # the small buckets are real extra programs, by design
    assert eng.compile_count == 5            # buckets {1, 4, 8, 64, 128}


def test_small_batch_results_match_full_bucket():
    """The floor path changes only the padded shape, never the results."""
    eng = _engine()
    q = _data(seed=5, n=64)
    d64, i64 = eng.search(q, 10)
    for nq in (1, 3, 8):
        d, ids = eng.search(q[:nq], 10)
        np.testing.assert_array_equal(np.asarray(i64)[:nq], np.asarray(ids))
        np.testing.assert_allclose(np.asarray(d64)[:nq], np.asarray(d),
                                   atol=1e-5)


def test_small_batch_zero_disables_floor_path():
    eng = _engine(small_batch=0)
    q = _data(seed=4, n=8)
    eng.search(q[:3], 10)
    assert eng.last_bucket == 64             # old behavior: pad to the floor
    assert eng.compile_count == 1


def test_bucket_padding_never_perturbs_results():
    """Every pipeline op is row-independent, so a batch served padded must
    equal the same rows served in a full bucket."""
    eng = _engine()
    q = _data(seed=4, n=64)
    d64, i64 = eng.search(q, 10)
    d7, i7 = eng.search(q[:7], 10)
    np.testing.assert_array_equal(np.asarray(i64)[:7], np.asarray(i7))
    np.testing.assert_allclose(np.asarray(d64)[:7], np.asarray(d7), atol=1e-5)


# --- functional core ---------------------------------------------------------

def test_engine_state_is_a_pytree():
    eng = _engine()
    leaves = jax.tree_util.tree_leaves(eng.state)
    assert leaves and all(isinstance(l, jax.Array) for l in leaves)
    # round-trips through tree_map (the property sharding/donation rely on)
    state2 = jax.tree_util.tree_map(lambda a: a, eng.state)
    assert isinstance(state2, EngineState)
    d1, i1 = eng.search(_data(seed=5, n=8), 5)
    eng.state = state2
    d2, i2 = eng.search(_data(seed=5, n=8), 5)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_search_fn_matches_engine_and_staged_pipeline():
    """The pure fused function == the engine wrapper == the staged pipeline
    (separate probe/scan + re-rank programs) on the same state."""
    eng = _engine()
    q = _data(seed=6, n=32)
    d_e, i_e = eng.search(q, 10)
    # pure call, no engine, no padding
    d_f, i_f = search_fn(eng.state, q, 10, nprobe=8, rerank=64)
    np.testing.assert_array_equal(np.asarray(i_e), np.asarray(i_f))
    np.testing.assert_allclose(np.asarray(d_e), np.asarray(d_f), atol=1e-5)
    # staged: the pre-fusion per-stage pipeline, stage by stage (the tagged
    # union's payload is the plain IVFPQIndex)
    assert eng.state.index.kind == "ivfpq"
    _, cand = ivfpq_search(eng.state.index.payload, q, 64, nprobe=8)
    d_s, i_s = jax.jit(exact_rerank, static_argnames="k")(
        q, eng.state.corpus, cand, k=10)
    np.testing.assert_array_equal(np.asarray(i_e), np.asarray(i_s))
    np.testing.assert_allclose(np.asarray(d_e), np.asarray(d_s), atol=1e-5)


def test_knob_change_rekeys_cache_not_state():
    eng = _engine()
    q = _data(seed=7, n=16)
    _, i1 = eng.search(q, 10)
    eng.config = dataclasses.replace(eng.config, nprobe=16)
    _, i2 = eng.search(q, 10)
    assert eng.compile_count == 2
    rec = recall_at_k(i1, i2)            # more probes only add candidates
    assert float(rec) > 0.5


# --- re-rank: masked gather + dedupe ----------------------------------------

def test_rerank_dedupes_candidates():
    """Duplicate candidate ids must yield each id at most once in the top-k
    (over-retrieval across probes must not waste re-rank slots)."""
    x = _data(seed=8, n=50)
    q = x[:4]
    cand = jnp.tile(jnp.arange(12)[None, :], (4, 4))     # each id 4 times
    d, ids = jax.jit(exact_rerank, static_argnames="k")(q, x, cand, k=12)
    ids = np.asarray(ids)
    for row in ids:
        real = row[row >= 0]
        assert len(set(real.tolist())) == len(real), row


def test_rerank_masked_gather_ignores_pads():
    x = _data(seed=9, n=30)
    q = x[:3]
    cand = jnp.full((3, 8), -1, jnp.int32)
    cand = cand.at[:, 2].set(jnp.arange(3))
    d, ids = jax.jit(exact_rerank, static_argnames="k")(q, x, cand, k=4)
    d, ids = np.asarray(d), np.asarray(ids)
    np.testing.assert_array_equal(ids[:, 0], np.arange(3))   # self-match
    assert (d[:, 0] < 1e-3).all()
    assert (ids[:, 1:] == -1).all() and np.isinf(d[:, 1:]).all()


# --- config ------------------------------------------------------------------

def test_serveconfig_rejects_bad_lut_dtype_and_bucket():
    with pytest.raises(ValueError, match="lut_dtype"):
        ServeConfig(lut_dtype="fp8")
    with pytest.raises(ValueError, match="query_bucket"):
        ServeConfig(query_bucket=0)
    with pytest.raises(ValueError, match="small_batch"):
        ServeConfig(small_batch=-1)
