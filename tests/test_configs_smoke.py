"""Per-architecture smoke tests: reduced config, one real train/serve step
on CPU, finite outputs + expected shapes (deliverable (f))."""
import pytest

from repro.configs import all_arch_names, get_arch

ARCHS = all_arch_names()


@pytest.mark.parametrize("name", ARCHS)
def test_arch_smoke(name):
    arch = get_arch(name)
    out = arch.smoke()
    assert out["ok"], out


def test_registry_complete():
    assert len(ARCHS) == 10
    fams = {get_arch(a).family for a in ARCHS}
    assert fams == {"lm", "gnn", "recsys"}


def test_cells_account_for_40():
    cells = sum(len(get_arch(a).shapes) for a in ARCHS)
    assert cells == 40
    skips = [(a, s.name) for a in ARCHS
             for s in get_arch(a).shapes.values() if s.skip]
    # long_500k documented-skips: all pure-full-attention LMs
    assert sorted(skips) == [
        ("granite-moe-1b-a400m", "long_500k"), ("olmoe-1b-7b", "long_500k"),
        ("stablelm-1.6b", "long_500k"), ("tinyllama-1.1b", "long_500k")]


@pytest.mark.parametrize("name", ARCHS)
def test_abstract_args_build(name):
    """ShapeDtypeStructs for every runnable cell build without allocation."""
    arch = get_arch(name)
    for sname in arch.runnable_shapes():
        args = arch.abstract_args(sname)
        assert isinstance(args, tuple) and len(args) >= 2
        flops = arch.model_flops(sname)
        assert flops > 0, (name, sname)
