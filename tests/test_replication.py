"""Replication & operations layer: WAL shipping + follower catch-up,
incremental snapshot chains, group commit.

The contracts pinned here:

* **follower parity** — a follower seeded from a primary snapshot and
  caught up through the shipped WAL serves search ids identical to the
  primary at EVERY record boundary, for flat / ivf / pq / ivfpq —
  including across primary-side compaction and policy vacuum, which the
  follower re-folds from the logged RT_COMPACT / RT_POLICY records
  (folded arrays never ship).
* **divergence** — a seq gap (the primary truncated history past the
  follower), a CRC failure mid-shipment, or a rewound source raises
  ``DivergenceError`` with re-seed instructions; a re-seeded follower
  rejoins. Followers reject local writes; a primary cannot catch_up.
* **incremental snapshots** — ``save(dir, incremental=True)`` writes a
  delta-only chain link that ``load_engine`` resolves against the full
  base; base-rewriting maintenance dirties the chain (full save
  required); the chained base pins the WAL truncation floor so a
  follower seeded from the base artifact can always catch up.
* **group commit** — concurrent ``fsync="always"`` appends under
  ``group_commit_ms`` coalesce into shared fsyncs with exact-once,
  in-order records; append returns only after a covering sync.
"""
import json
import os
import shutil
import threading

import jax
import numpy as np
import pytest

from repro.core import MPADConfig
from repro.runtime.fault import FailureInjector
from repro.search import (DivergenceError, DurabilityConfig, LocalDirSource,
                          PolicyConfig, ReplicationError, SearchEngine,
                          ServeConfig, StreamConfig, Wal, catch_up,
                          load_engine, seed_follower)
from repro.search.durability.wal import (RT_UPSERT, decode_upsert,
                                         encode_upsert, iter_records)

pytestmark = pytest.mark.replication

N, DIM, K = 600, 32, 10


def _data(seed=0, n=N, d=DIM):
    key = jax.random.key(seed)
    centers = jax.random.normal(key, (12, d)) * 2
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 12)
    return centers[lab] + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 2), (n, d))


def _queries(nq=16):
    x = _data()
    return x[:nq] + 0.02 * jax.random.normal(jax.random.key(9), (nq, DIM))


def _cfg(index, target_dim=None, **stream_kw):
    stream_kw.setdefault("delta_capacity", 64)
    kw = dict(target_dim=target_dim, rerank=128, index=index,
              mpad=MPADConfig(m=8, iters=16) if target_dim else None,
              fit_sample=512, stream=StreamConfig(**stream_kw))
    if index in ("ivf", "ivfpq"):
        kw.update(nlist=12, nprobe=12)
    if index in ("pq", "ivfpq"):
        kw.update(pq_subspaces=8, pq_centroids=64)
    return ServeConfig(**kw)


def _rows(seed, n):
    return np.asarray(_data(seed=seed, n=n), np.float32)


# each op sized under the delta compact point (48 of 64): ops map 1:1
# onto WAL records, so an op boundary IS a record boundary
_OPS = [
    ("upsert", np.arange(600, 630, dtype=np.int32), 1),
    ("delete", np.asarray([3, 5, 600, 604], np.int32), None),
    ("upsert", np.arange(625, 640, dtype=np.int32), 2),
    ("compact", None, None),
    ("upsert", np.arange(640, 670, dtype=np.int32), 3),
    ("delete", np.asarray([10, 11, 650], np.int32), None),
    ("upsert", np.arange(7, 12, dtype=np.int32), 4),
]


def _apply_ops(eng, ops):
    for op, ids, seed in ops:
        if op == "upsert":
            eng.upsert(ids, _rows(seed, len(ids)))
        elif op == "delete":
            eng.delete(ids)
        else:
            eng.compact()


def _ids(eng, q):
    return np.asarray(eng.search(q, K)[1])


def _primary(tmp_path, index="flat", dcfg=None, **stream_kw):
    live = str(tmp_path / "live")
    eng = SearchEngine(_data(), _cfg(index, **stream_kw)).durable(
        live, dcfg or DurabilityConfig(fsync="batch"))
    return eng, live


# --- follower catch-up parity ------------------------------------------------

@pytest.mark.parametrize("index", ("flat", "ivf", "pq", "ivfpq"))
def test_follower_parity_at_every_record_boundary(index, tmp_path):
    """The acceptance property: after every primary op (one WAL record),
    one catch_up pass lands the follower on search ids identical to the
    primary — including across the compaction barrier at op 4, which the
    follower re-folds from the RT_COMPACT record."""
    q = _queries()
    eng, live = _primary(tmp_path, index)
    fol = seed_follower(live)
    src = LocalDirSource(live)
    np.testing.assert_array_equal(_ids(fol, q), _ids(eng, q))  # boundary 0
    for i, op in enumerate(_OPS):
        _apply_ops(eng, [op])
        eng._wal.sync()
        st = catch_up(fol, src)
        assert st.records >= 1 and st.lag_seq == 0
        np.testing.assert_array_equal(_ids(fol, q), _ids(eng, q),
                                      err_msg=f"boundary {i + 1}")
    # caught up: the next pass is a cheap no-op, and the typed metrics
    # surface reports the replica position
    again = catch_up(fol, src)
    assert again.records == 0 and again.lag_seq == 0
    m = fol.metrics()
    assert m.replication is not None
    assert m.replication.follower_lag_seq == 0
    assert m.replication.applied_seq == eng._wal.last_seq


def test_follower_refolds_vacuum_from_policy_record(tmp_path):
    """A primary-side policy vacuum ships as RT_DELETE + RT_POLICY: the
    follower runs the reclaim with its own write programs and lands on
    identical ids — no folded arrays move."""
    q = _queries()
    eng, live = _primary(tmp_path, "ivf",
                         policy=PolicyConfig(tombstone_density=0.2,
                                             tombstone_min_dead=32))
    fol = seed_follower(live)
    eng.delete(np.arange(200, 500, dtype=np.int32))   # triggers vacuum
    assert eng.metrics().compact.vacuums == 1
    eng._wal.sync()
    st = catch_up(fol, LocalDirSource(live))
    assert st.deletes == 1 and st.policies == 1
    assert fol.metrics().compact.vacuums == 1
    np.testing.assert_array_equal(_ids(fol, q), _ids(eng, q))
    got = _ids(fol, q)
    assert not np.any((got >= 200) & (got < 500))


def test_crash_mid_catch_up_reseeds_cleanly(tmp_path):
    """A follower killed mid-catch-up (inside the re-fold of a shipped
    compaction) did not advance its position; the operator re-seeds a
    fresh follower from the snapshot and it reaches parity."""
    q = _queries()
    eng, live = _primary(tmp_path, "ivf")
    _apply_ops(eng, _OPS)
    eng._wal.sync()
    fol = seed_follower(live)
    injector = FailureInjector(fail_at={"compact_begin"})
    fol.crash_hook = injector.maybe_fail
    pos = fol._applied_seq
    with pytest.raises(RuntimeError, match="injected failure"):
        catch_up(fol, LocalDirSource(live))
    assert fol._applied_seq == pos       # position advances only on success
    fresh = seed_follower(live)
    st = catch_up(fresh, LocalDirSource(live))
    assert st.records == len(_OPS)
    np.testing.assert_array_equal(_ids(fresh, q), _ids(eng, q))


# --- divergence --------------------------------------------------------------

def test_divergence_on_truncated_history(tmp_path):
    """A full snapshot moves the WAL floor and truncates history; a
    follower seeded before it cannot rejoin by tailing (seq gap), and the
    error says so; re-seeding from the fresh snapshot rejoins."""
    q = _queries()
    eng, live = _primary(
        tmp_path, "flat",
        dcfg=DurabilityConfig(fsync="batch", segment_bytes=256))
    stale_seed = str(tmp_path / "stale")
    shutil.copytree(live, stale_seed)
    _apply_ops(eng, _OPS[:3])
    eng.save(live)                        # floor moves; prefix truncated
    _apply_ops(eng, _OPS[3:])
    eng._wal.sync()
    stale = seed_follower(stale_seed)
    with pytest.raises(DivergenceError, match="re-seed"):
        catch_up(stale, LocalDirSource(live))
    reseed = str(tmp_path / "reseed")
    shutil.copytree(live, reseed, ignore=shutil.ignore_patterns("wal"))
    fol = seed_follower(reseed)
    catch_up(fol, LocalDirSource(live))
    np.testing.assert_array_equal(_ids(fol, q), _ids(eng, q))


def test_divergence_on_corrupt_shipment(tmp_path):
    """CRC damage before the tail of the shipped stream is not a torn
    tail: catch_up refuses to apply past it and demands a re-seed."""
    eng, live = _primary(
        tmp_path, "flat",
        dcfg=DurabilityConfig(fsync="batch", segment_bytes=256))
    _apply_ops(eng, _OPS)
    eng._wal.sync()
    ship = str(tmp_path / "ship")
    shutil.copytree(os.path.join(live, "wal"), ship)
    segs = sorted(f for f in os.listdir(ship) if f.endswith(".log"))
    assert len(segs) > 2, "256-byte segments must have rotated"
    path = os.path.join(ship, segs[1])    # mid-stream, NOT the last segment
    data = bytearray(open(path, "rb").read())
    data[-1] ^= 0xFF
    open(path, "wb").write(bytes(data))
    fol = seed_follower(live)
    with pytest.raises(DivergenceError, match="[Rr]e-seed"):
        catch_up(fol, LocalDirSource(ship))


def test_divergence_on_rewound_source(tmp_path):
    """A source whose tail is behind the follower's applied position is
    not the history the follower came from."""
    eng, live = _primary(tmp_path, "flat")
    stale_src = str(tmp_path / "stale")
    shutil.copytree(live, stale_src)
    _apply_ops(eng, _OPS[:2])
    eng._wal.sync()
    fol = seed_follower(live)
    catch_up(fol, LocalDirSource(live))   # follower is ahead of stale_src
    with pytest.raises(DivergenceError, match="rewound"):
        catch_up(fol, LocalDirSource(stale_src))


def test_follower_rejects_local_writes_and_role_misuse(tmp_path):
    """One history, one writer: followers reject upsert/delete and cannot
    open a local WAL; a WAL-owning primary cannot catch_up; a read-only
    engine cannot be a follower target."""
    eng, live = _primary(tmp_path, "flat")
    fol = seed_follower(live)
    with pytest.raises(ReplicationError, match="follower"):
        fol.upsert(np.asarray([900], np.int32), _rows(1, 1))
    with pytest.raises(ReplicationError, match="follower"):
        fol.delete(np.asarray([3], np.int32))
    with pytest.raises(ReplicationError, match="follower"):
        fol.durable(str(tmp_path / "fwal"))
    with pytest.raises(ReplicationError, match="primary"):
        catch_up(eng, LocalDirSource(live))
    ro = SearchEngine(_data(), ServeConfig(index="flat"))
    with pytest.raises(ReplicationError, match="streaming"):
        catch_up(ro, LocalDirSource(live))
    fresh = SearchEngine(_data(), _cfg("flat"))
    with pytest.raises(ValueError, match="follower"):
        fresh.durable(str(tmp_path / "d2"),
                      DurabilityConfig(role="follower"))


def test_durability_config_validation():
    with pytest.raises(ValueError, match="role"):
        DurabilityConfig(role="observer")
    with pytest.raises(ValueError, match="group_commit_ms"):
        DurabilityConfig(group_commit_ms=-1.0)
    with pytest.raises(ValueError, match="always"):
        DurabilityConfig(fsync="batch", group_commit_ms=2.0)
    with pytest.raises(ValueError, match="always"):
        DurabilityConfig(fsync="never", group_commit_ms=2.0)
    DurabilityConfig(fsync="always", group_commit_ms=2.0)   # coherent


# --- incremental snapshots ---------------------------------------------------

def test_incremental_snapshot_chain_roundtrip(tmp_path):
    """Delta-only chain links restore exactly: load resolves base +
    newest incremental, each link supersedes the previous, and the link
    is a fraction of the full checkpoint's bytes."""
    q = _queries()
    eng, live = _primary(tmp_path, "flat")
    base_meta = json.load(open(os.path.join(live, "engine.json")))
    full_bytes = os.path.getsize(os.path.join(live, base_meta["ckpt"]))
    eng.upsert(np.arange(600, 620, dtype=np.int32), _rows(1, 20))
    p1 = eng.save(live, incremental=True)
    assert os.path.getsize(p1) < 0.5 * full_bytes
    meta = json.load(open(os.path.join(live, "engine.json")))
    assert meta["incremental"] and meta["base_ckpt"] == base_meta["ckpt"]
    assert len(meta["chain"]) == 2
    np.testing.assert_array_equal(_ids(load_engine(live), q), _ids(eng, q))
    # second link: delete + overwrite land in the delta state only
    eng.delete(np.asarray([3, 610], np.int32))
    eng.upsert(np.arange(615, 625, dtype=np.int32), _rows(2, 10))
    eng.save(live, incremental=True)
    meta = json.load(open(os.path.join(live, "engine.json")))
    assert len(meta["chain"]) == 3
    assert eng.metrics().snapshot.chain_depth == 2
    rec = load_engine(live)
    np.testing.assert_array_equal(_ids(rec, q), _ids(eng, q))
    # the restored engine replays nothing: the chain covered the log
    assert rec._replayed == 0


def test_incremental_requires_clean_durable_base(tmp_path):
    """The chain invariants are enforced with actionable errors: no
    durable base, base-rewriting maintenance, or a read-only engine all
    refuse the delta-only path; a fresh full save reopens it."""
    eng, live = _primary(tmp_path, "flat")
    with pytest.raises(ValueError, match="durable base"):
        eng.save(str(tmp_path / "elsewhere"), incremental=True)
    eng.upsert(np.arange(600, 660, dtype=np.int32), _rows(1, 60))
    # the auto-compaction rewrote the base arrays: chain is dead
    assert eng.metrics().compact.compactions >= 1
    with pytest.raises(ValueError, match="full snapshot"):
        eng.save(live, incremental=True)
    eng.save(live)                       # new base, new chain
    eng.upsert(np.arange(700, 710, dtype=np.int32), _rows(2, 10))
    eng.save(live, incremental=True)     # chains again
    q = _queries()
    np.testing.assert_array_equal(_ids(load_engine(live), q), _ids(eng, q))
    free = SearchEngine(_data(), _cfg("flat"))
    with pytest.raises(ValueError, match="durable base"):
        free.save(str(tmp_path / "free"), incremental=True)
    ro = SearchEngine(_data(), ServeConfig(index="flat"))
    with pytest.raises(ValueError, match="read-only"):
        ro.save(str(tmp_path / "ro"), incremental=True)


def test_crash_mid_incremental_save_falls_back(tmp_path):
    """A crash between the incremental array write and the manifest
    commit leaves the previous manifest + WAL tail fully loadable, and a
    retry completes the chain."""
    q = _queries()
    eng, live = _primary(tmp_path, "flat")
    eng.upsert(np.arange(600, 620, dtype=np.int32), _rows(1, 20))
    want = _ids(eng, q)
    injector = FailureInjector(fail_at={"snapshot_arrays"})
    eng.crash_hook = injector.maybe_fail
    with pytest.raises(RuntimeError, match="injected failure"):
        eng.save(live, incremental=True)
    rec = load_engine(live)              # old manifest + replayed tail
    assert rec._replayed == 1
    np.testing.assert_array_equal(_ids(rec, q), want)
    eng.crash_hook = None
    eng.save(live, incremental=True)     # retry commits
    rec = load_engine(live)
    assert rec._replayed == 0
    np.testing.assert_array_equal(_ids(rec, q), want)


def test_incremental_pins_wal_floor_for_base_followers(tmp_path):
    """Incremental truncation keeps every record past the chain BASE —
    they are what re-seeds a follower built from the base artifact — and
    the floor shows up in the WAL stats; a full save moves it."""
    q = _queries()
    eng, live = _primary(
        tmp_path, "flat",
        dcfg=DurabilityConfig(fsync="batch", segment_bytes=256))
    base_seed = str(tmp_path / "seed")
    shutil.copytree(live, base_seed)
    base_seq = eng._wal.last_seq
    for s in range(3):
        eng.upsert(np.arange(600 + 10 * s, 610 + 10 * s, dtype=np.int32),
                   _rows(s, 10))
    eng.save(live, incremental=True)
    assert eng._wal.stats()["floor_seq"] == base_seq
    # every record past the base survived the truncation
    seqs = [s for s, _, _ in
            iter_records(os.path.join(live, "wal"), after=base_seq)]
    assert seqs[0] == base_seq + 1
    eng.upsert(np.arange(630, 640, dtype=np.int32), _rows(7, 10))
    eng._wal.sync()
    fol = seed_follower(base_seed)
    catch_up(fol, LocalDirSource(live))
    np.testing.assert_array_equal(_ids(fol, q), _ids(eng, q))
    # a FULL save is a new chain base: the floor moves with it and the
    # old base artifact can no longer tail this log
    eng.save(live)
    assert eng._wal.stats()["floor_seq"] > base_seq
    eng.upsert(np.arange(650, 660, dtype=np.int32), _rows(8, 10))
    eng._wal.sync()
    stale = seed_follower(base_seed)
    with pytest.raises(DivergenceError, match="re-seed"):
        catch_up(stale, LocalDirSource(live))


# --- group commit ------------------------------------------------------------

def test_group_commit_concurrent_appends_exact_once(tmp_path):
    """8 threads of fsync=always appends under a 2 ms gather window land
    exact-once, in seq order, with far fewer fsyncs than records — and
    every append returned only after a covering sync."""
    d = str(tmp_path / "wal")
    wal = Wal(d, DurabilityConfig(fsync="always", group_commit_ms=2.0))
    n_threads, per = 8, 24
    def writer(t):
        for i in range(per):
            rid = np.asarray([t * per + i], np.int32)
            wal.append(RT_UPSERT,
                       encode_upsert(rid, np.full((1, 4), float(t),
                                                  np.float32)))
    threads = [threading.Thread(target=writer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    st = wal.stats()
    total = n_threads * per
    assert st["records"] == total
    assert st["durable_seq"] == st["last_seq"] == total - 1
    assert st["fsyncs"] < total          # coalesced
    assert st["group_commits"] >= 1
    wal.close()
    got = list(iter_records(d))
    assert [s for s, _, _ in got] == list(range(total))
    ids = sorted(int(decode_upsert(p)[0][0]) for _, _, p in got)
    assert ids == list(range(total))


def test_group_commit_append_returns_durable(tmp_path):
    """The durability contract is unchanged by grouping: append (and a
    multi-chunk engine write batch) returns only once the covering fsync
    has run."""
    d = str(tmp_path / "wal")
    wal = Wal(d, DurabilityConfig(fsync="always", group_commit_ms=2.0))
    seq = wal.append(RT_UPSERT, encode_upsert(
        np.asarray([1], np.int32), np.ones((1, 4), np.float32)))
    assert wal.stats()["durable_seq"] >= seq
    wal.close()
    eng, live = _primary(
        tmp_path, "flat",
        dcfg=DurabilityConfig(fsync="always", group_commit_ms=2.0))
    # 100 rows = 3 chunks: each appends wait=False, the batch waits once
    eng.upsert(np.arange(600, 700, dtype=np.int32), _rows(1, 100))
    st = eng._wal.stats()
    assert st["durable_seq"] == st["last_seq"]
    assert st["group_commit_ms"] == 2.0


def test_group_commit_crash_after_append_recovers_the_write(tmp_path):
    """A crash right after the WAL append (before the engine touched the
    store) loses nothing: the grouped record is on disk and recovery
    replays it — the log stays ahead of the store under grouping too."""
    q = _queries()
    eng, live = _primary(
        tmp_path, "flat",
        dcfg=DurabilityConfig(fsync="always", group_commit_ms=2.0))
    injector = FailureInjector(fail_at={"wal_appended"})
    eng.crash_hook = injector.maybe_fail
    with pytest.raises(RuntimeError, match="injected failure"):
        eng.upsert(np.arange(600, 620, dtype=np.int32), _rows(1, 20))
    eng._wal.close()                     # the simulated process death
    rec = load_engine(live)
    assert rec._replayed == 1
    oracle = SearchEngine(_data(), _cfg("flat"))
    oracle.upsert(np.arange(600, 620, dtype=np.int32), _rows(1, 20))
    np.testing.assert_array_equal(_ids(rec, q), _ids(oracle, q))
