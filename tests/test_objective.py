"""Unit tests: paper-faithful objective vs the sorted fast path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (find_quantile_threshold, mu_b_exact_value_and_grad,
                        mu_b_fast, mu_b_fast_value_and_grad,
                        num_selected_pairs, orthogonality_penalty,
                        threshold_stats)


def _data(n=200, d=16, seed=0):
    x = jax.random.normal(jax.random.key(seed), (n, d))
    w = jax.random.normal(jax.random.key(seed + 1), (d,))
    return x, w / jnp.linalg.norm(w)


@pytest.mark.parametrize("b", [5.0, 25.0, 50.0, 80.0, 100.0])
def test_exact_matches_fast(b):
    x, w = _data()
    ve, ge = mu_b_exact_value_and_grad(w, x, b=b)
    vf, gf = mu_b_fast_value_and_grad(w, x, b=b)
    np.testing.assert_allclose(ve, vf, rtol=1e-5, atol=1e-6)
    # subgradient at the selection boundary: f32 rounding may swap a couple
    # of boundary pairs in/out of D_b (each contributes ~|x_i-x_j|/K), so
    # small-b gradients agree to ~1e-3 absolute, not elementwise-exactly.
    np.testing.assert_allclose(ge, gf, atol=5e-3)
    cos = float(jnp.dot(ge, gf) /
                (jnp.linalg.norm(ge) * jnp.linalg.norm(gf) + 1e-12))
    assert cos > 0.999, cos


def test_custom_vjp_matches_autodiff_oracle():
    x, w = _data(150, 8, seed=3)
    g1 = jax.grad(lambda w_: mu_b_fast(w_, x, b=70.0))(w)
    _, g2 = mu_b_exact_value_and_grad(w, x, b=70.0)
    np.testing.assert_allclose(g1, g2, rtol=1e-3, atol=1e-5)


def test_num_selected_pairs():
    assert num_selected_pairs(100, 100.0) == 100 * 99 // 2
    assert num_selected_pairs(100, 1e-9) == 1          # never zero
    assert num_selected_pairs(10, 50.0) == 22


def test_quantile_threshold_matches_numpy():
    """tau converges to the k-th smallest pairwise diff within f32 rounding
    (the bisection counts via searchsorted(ps, ps - t), whose rounding can
    differ from direct (p_i - p_j) <= t by 1 ulp at the boundary)."""
    p = np.asarray(jax.random.normal(jax.random.key(5), (300,)))
    diffs = np.abs(p[:, None] - p[None, :])[np.triu_indices(300, 1)]
    for k in [1, 10, 1000, len(diffs)]:
        tau = float(find_quantile_threshold(jnp.asarray(p), k))
        kth = float(np.sort(diffs)[k - 1])
        assert abs(tau - kth) <= 1e-5 * max(abs(kth), 1e-3) + 1e-7, (tau, kth)
        assert k - 2 <= (diffs <= tau).sum() <= k + 2
        # tau is tight: clearly below it selects < k pairs
        assert (diffs <= tau * (1 - 1e-4) - 1e-7).sum() < k


def test_threshold_stats_counts():
    p = jnp.asarray([0.0, 0.1, 0.25, 1.0])
    st_ = threshold_stats(p, jnp.float32(0.3))
    # pairs within 0.3: (0,.1) (0,.25) (.1,.25) -> 3
    assert int(st_.count) == 3
    np.testing.assert_allclose(float(st_.sum), 0.1 + 0.25 + 0.15, atol=1e-6)
    # coefficients: c_i = (#below within tau) - (#above within tau)
    np.testing.assert_allclose(st_.coeff, [-2.0, 0.0, 2.0, 0.0])


def test_orthogonality_penalty():
    w = jnp.array([1.0, 0.0])
    prev = jnp.array([[0.0, 1.0]])
    assert float(orthogonality_penalty(w, prev, 5.0)) == 0.0
    prev2 = jnp.array([[1.0, 0.0], [0.6, 0.8]])
    np.testing.assert_allclose(
        float(orthogonality_penalty(w, prev2, 2.0)), 2.0 * (1 + 0.36),
        rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(10, 80), st.floats(10.0, 100.0), st.integers(0, 10**6))
def test_boundedness_property(n, b, seed):
    """Paper Sec 3.6: mu_b(w) <= D_max (Cauchy-Schwarz)."""
    x = jax.random.normal(jax.random.key(seed), (n, 5))
    w = jax.random.normal(jax.random.key(seed + 1), (5,))
    w = w / jnp.linalg.norm(w)
    v, _ = mu_b_fast_value_and_grad(w, x, b=b)
    d = jnp.sqrt(jnp.sum(
        (x[:, None, :] - x[None, :, :]) ** 2, -1))
    assert float(v) <= float(jnp.max(d)) + 1e-4


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10**6))
def test_mu_monotone_in_b(seed):
    """Mean of the smallest-b% set is nondecreasing in b."""
    x = jax.random.normal(jax.random.key(seed), (60, 6))
    w = jax.random.normal(jax.random.key(seed + 1), (6,))
    w = w / jnp.linalg.norm(w)
    vals = [float(mu_b_fast_value_and_grad(w, x, b=b)[0])
            for b in (10.0, 40.0, 70.0, 100.0)]
    assert all(vals[i] <= vals[i + 1] + 1e-5 for i in range(len(vals) - 1))


def test_rotation_invariance():
    """mu_b(Rw; RX) == mu_b(w; X) — paper's affine-robustness claim."""
    x, w = _data(100, 6, seed=7)
    q, _ = jnp.linalg.qr(jax.random.normal(jax.random.key(9), (6, 6)))
    v1, _ = mu_b_fast_value_and_grad(w, x, b=80.0)
    v2, _ = mu_b_fast_value_and_grad(q @ w, x @ q.T, b=80.0)
    np.testing.assert_allclose(v1, v2, rtol=1e-4)
