"""Flash-attention Pallas kernel vs oracle: shape/GQA/window sweeps +
hypothesis, plus the custom-VJP train path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import (attention_ref, flash_attention,
                                           flash_attention_fwd)


@pytest.mark.parametrize("b,sq,h,kv,dh,win,bq,bk", [
    (2, 64, 4, 2, 16, None, 16, 32),
    (1, 128, 8, 8, 32, None, 32, 32),
    (2, 96, 6, 2, 8, 24, 32, 32),
    (1, 64, 4, 1, 64, 16, 16, 16),
    (1, 80, 2, 2, 8, None, 16, 16),       # non-power-of-two seq
])
def test_flash_matches_ref(b, sq, h, kv, dh, win, bq, bk):
    key = jax.random.key(0)
    q = jax.random.normal(key, (b, sq, h, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, sq, kv, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, sq, kv, dh))
    out = flash_attention_fwd(q, k, v, window=win, block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, window=win)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)


def test_flash_bf16():
    key = jax.random.key(3)
    q = jax.random.normal(key, (1, 64, 4, 16)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 64, 2, 16)
                          ).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 64, 2, 16)
                          ).astype(jnp.bfloat16)
    out = flash_attention_fwd(q, k, v, block_q=16, block_k=16)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32))
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(out.astype(jnp.float32), ref, atol=3e-2)


def test_flash_custom_vjp_grads():
    """Backward (recompute through chunked path) == autodiff of the oracle."""
    key = jax.random.key(4)
    q = jax.random.normal(key, (1, 32, 4, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 32, 2, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 32, 2, 8))

    def f_kernel(q_, k_, v_):
        return jnp.sum(flash_attention(q_, k_, v_) ** 2)

    def f_ref(q_, k_, v_):
        return jnp.sum(attention_ref(q_, k_, v_) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=1e-3)


@settings(max_examples=8, deadline=None)
@given(st.integers(16, 96), st.sampled_from([1, 2, 4]),
       st.sampled_from([8, 16]), st.integers(0, 10**6))
def test_flash_property(sq, kv, dh, seed):
    sq = (sq // 16) * 16
    h = kv * 2
    q = jax.random.normal(jax.random.key(seed), (1, sq, h, dh))
    k = jax.random.normal(jax.random.key(seed + 1), (1, sq, kv, dh))
    v = jax.random.normal(jax.random.key(seed + 2), (1, sq, kv, dh))
    out = flash_attention_fwd(q, k, v, block_q=16, block_k=16)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=1e-3)
