"""Streaming index subsystem: delta segments, tombstones, compaction.

The contracts pinned here:

* **freshness** — upserted rows are searchable immediately (served exactly
  from the delta), deletes take effect immediately on both layers;
* **equivalence** — any interleaving of upserts/deletes followed by
  ``compact()`` returns the same top-k as rebuilding the index from
  scratch on the surviving rows with the same frozen quantizers
  (``rebuild_state``), for every index kind and LUT dtype;
* **jit stability** — interleaved upsert/delete/search on a 16k-row
  corpus never recompiles after warmup (``SearchEngine.compile_count``
  pinned); capacity overflow is the one declared recompile point
  (``grow_count``) and stays correct.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MPADConfig
from repro.search import (SearchEngine, ServeConfig, StreamConfig,
                          rebuild_state, search_fn)

pytestmark = pytest.mark.stream

N, DIM, K = 600, 32, 10


def _data(seed=0, n=N, d=DIM):
    key = jax.random.key(seed)
    centers = jax.random.normal(key, (12, d)) * 2
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 12)
    return centers[lab] + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 2), (n, d))


def _queries(nq=16):
    x = _data()
    return x[:nq] + 0.02 * jax.random.normal(jax.random.key(9), (nq, DIM))


def _cfg(index, lut="f32", target_dim=None, **stream_kw):
    stream_kw.setdefault("delta_capacity", 64)
    kw = dict(target_dim=target_dim, rerank=128, index=index,
              mpad=MPADConfig(m=8, iters=16) if target_dim else None,
              fit_sample=512, stream=StreamConfig(**stream_kw))
    # stage knobs only where the pipeline has the stage (dead knobs raise)
    if index in ("ivf", "ivfpq"):
        kw.update(nlist=12, nprobe=12)
    if index in ("pq", "ivfpq"):
        kw.update(pq_subspaces=8, pq_centroids=64, lut_dtype=lut)
    return ServeConfig(**kw)


def _engine(index, **kw):
    return SearchEngine(_data(), _cfg(index, **kw))


# --- freshness: the delta layer serves writes immediately --------------------

@pytest.mark.parametrize("index", ("flat", "ivf", "pq", "ivfpq"))
def test_fresh_stream_matches_static(index):
    """Before any write, the streaming engine is the static engine."""
    eng = _engine(index)
    static = SearchEngine(_data(), dataclasses.replace(eng.config,
                                                       stream=None))
    q = _queries()
    d1, i1 = eng.search(q, K)
    d2, i2 = static.search(q, K)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-5)


@pytest.mark.parametrize("index", ("flat", "ivfpq"))
def test_upsert_visible_immediately_and_exact(index):
    eng = _engine(index)
    q = _queries()
    new_ids = np.arange(N, N + q.shape[0])
    eng.upsert(new_ids, q)
    d, ids = eng.search(q, K)
    # each query's own upserted copy wins at distance ~0 — served exactly
    # from the delta, not through any quantizer
    np.testing.assert_array_equal(np.asarray(ids)[:, 0], new_ids)
    assert float(np.asarray(d)[:, 0].max()) < 1e-3


def test_upsert_overwrites_by_id():
    eng = _engine("ivfpq")
    q = _queries(4)
    far = 100.0 + jnp.zeros((4, DIM))
    eng.upsert(np.arange(N, N + 4), q)            # near the queries
    eng.upsert(np.arange(N, N + 4), far)          # same ids, far away
    _, ids = eng.search(q, K)
    assert not np.isin(np.arange(N, N + 4), np.asarray(ids)[:, 0]).any()
    # overwriting a BASE id tombstones the base copy
    base_id = int(np.asarray(eng.search(q[:1], 1)[1])[0, 0])
    eng.upsert(np.array([base_id]), far[:1])
    _, ids2 = eng.search(q[:1], K)
    assert base_id not in np.asarray(ids2)[0]


def test_delete_hides_base_and_delta_rows():
    eng = _engine("ivfpq")
    q = _queries(4)
    _, before = eng.search(q, K)
    top = np.asarray(before)[:, 0]
    eng.delete(top)                               # base rows
    _, after = eng.search(q, K)
    assert not np.isin(top, np.asarray(after)).any()
    eng.upsert(np.arange(N, N + 4), q)            # delta rows
    eng.delete(np.arange(N, N + 4))
    _, final = eng.search(q, K)
    assert not np.isin(np.arange(N, N + 4), np.asarray(final)).any()
    # deleting an absent id is a no-op
    eng.delete(np.array([10 ** 6]))
    _, again = eng.search(q, K)
    np.testing.assert_array_equal(np.asarray(final), np.asarray(again))


def test_reupsert_after_delete_resurfaces():
    eng = _engine("flat")
    q = _queries(2)
    eng.upsert(np.array([N, N + 1]), q)
    eng.delete(np.array([N, N + 1]))
    eng.upsert(np.array([N, N + 1]), q)
    _, ids = eng.search(q, K)
    np.testing.assert_array_equal(np.asarray(ids)[:, 0], [N, N + 1])


# --- equivalence: interleavings + compact == rebuild from scratch ------------

def _apply_random_ops(eng, rng, steps=8):
    """Random interleaving of upserts (new ids + overwrites) and deletes;
    returns the surviving {id: vector} map."""
    alive = {i: np.asarray(_data()[i]) for i in range(N)}
    next_id = N
    for _ in range(steps):
        if rng.rand() < 0.6:
            b = rng.randint(1, 20)
            ids, vecs = [], []
            for _ in range(b):
                if alive and rng.rand() < 0.3:
                    i = int(rng.choice(list(alive)))
                else:
                    i, next_id = next_id, next_id + 1
                v = rng.randn(DIM).astype(np.float32)
                ids.append(i), vecs.append(v)
                alive[i] = v
            eng.upsert(np.array(ids), np.stack(vecs))
        else:
            ids = [int(i) for i in rng.choice(
                list(alive), size=min(rng.randint(1, 10), len(alive)),
                replace=False)]
            for i in ids:
                del alive[i]
            eng.delete(np.array(ids))
    return alive


@pytest.mark.parametrize("index,lut,target_dim", [
    ("flat", "f32", None), ("ivf", "f32", None), ("pq", "f32", None),
    ("ivfpq", "f32", None), ("flat", "f32", 8), ("ivfpq", "f32", 8),
    ("ivfpq", "int8", None), ("ivfpq", "int8", 8), ("pq", "int8", None),
])
@pytest.mark.parametrize("seed", (3, 7))
def test_interleaved_ops_then_compact_equals_rebuild(index, lut, target_dim,
                                                     seed):
    """The acceptance property: post-compaction streaming search returns
    the same top-k ids as a from-scratch rebuild over the surviving rows
    with the same frozen quantizers."""
    eng = SearchEngine(_data(), _cfg(index, lut=lut, target_dim=target_dim))
    rng = np.random.RandomState(seed)
    alive = _apply_random_ops(eng, rng)
    eng.compact()
    assert int(eng.store.delta_count) == 0
    surv_ids = np.array(sorted(alive))
    surv = jnp.asarray(np.stack([alive[i] for i in surv_ids]))
    oracle = rebuild_state(eng.frozen, surv, index=index)
    coded = index in ("pq", "ivfpq")
    q = _queries()
    d_r, i_r = search_fn(oracle, q, K, nprobe=12, rerank=128,
                         backend="jnp", interpret=True,
                         lut_dtype=lut if coded else "f32")
    ext_r = surv_ids[np.asarray(i_r)]
    d_s, i_s = eng.search(q, K)
    np.testing.assert_array_equal(np.sort(np.asarray(i_s), axis=1),
                                  np.sort(ext_r, axis=1))
    np.testing.assert_allclose(np.sort(np.asarray(d_s), axis=1),
                               np.sort(np.asarray(d_r), axis=1), atol=1e-4)


# --- jit stability: no recompiles after warmup -------------------------------

def test_interleaved_16k_never_recompiles_after_warmup():
    """The acceptance pin: a 16k-row streaming ivfpq engine serving an
    interleaved upsert/delete/search workload (including auto-compactions)
    holds its compile count constant after one warmup of each op."""
    n, d = 16384, DIM
    key = jax.random.key(0)
    centers = jax.random.normal(key, (64, d)) * 2
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 64)
    x = centers[lab] + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 2), (n, d))
    eng = SearchEngine(x, ServeConfig(
        target_dim=None, rerank=64, index="ivfpq", nlist=64, nprobe=8,
        pq_subspaces=8, pq_centroids=256,
        stream=StreamConfig(delta_capacity=128, write_bucket=64,
                            row_capacity=n + 4096, cell_slack=2048)))
    q = jnp.asarray(x[:64])
    rng = np.random.RandomState(0)
    # warmup: one of each program (search bucket, write bucket, compact)
    eng.search(q, K)
    eng.upsert(np.arange(n, n + 32), rng.randn(32, d).astype(np.float32))
    eng.delete(np.arange(n, n + 8))
    eng.compact()
    eng.search(q, K)
    cc = eng.compile_count
    for step in range(40):                     # crosses the auto-compact
        eng.upsert(np.arange(n + 100 + 32 * step, n + 132 + 32 * step),
                   rng.randn(32, d).astype(np.float32))
        eng.delete(rng.randint(0, n, size=8).astype(np.int32))
        eng.search(q, K)
    assert eng.grow_count == 0
    assert eng.compile_count == cc, (cc, eng.compile_count)


def test_write_buckets_share_compilations():
    # delta_capacity high enough that the loop never auto-compacts
    eng = _engine("flat", write_bucket=32, delta_capacity=256)
    rng = np.random.RandomState(0)
    eng.upsert(np.array([N]), rng.randn(1, DIM).astype(np.float32))
    cc = eng.compile_count
    for b in (1, 5, 17, 32):                  # all inside the 32-bucket
        eng.upsert(np.arange(N, N + b), rng.randn(b, DIM).astype(np.float32))
        eng.delete(np.arange(N, N + b))
    assert eng.compile_count == cc + 1        # +1: the delete program


def test_delta_overflow_auto_compacts():
    """One upsert call larger than the delta capacity streams through in
    chunks with compactions in between — nothing is lost."""
    eng = _engine("ivfpq", delta_capacity=32)
    rng = np.random.RandomState(1)
    nb = 100
    vecs = rng.randn(nb, DIM).astype(np.float32)
    eng.upsert(np.arange(N, N + nb), vecs)
    _, ids = eng.search(jnp.asarray(vecs[:8]), 1)
    np.testing.assert_array_equal(np.asarray(ids)[:, 0],
                                  np.arange(N, N + 8))


def test_compact_overflow_grows_and_stays_correct():
    """Under-provisioned capacity: compaction detects the overflow, grows
    host-side (the declared recompile point), retries, and serves the
    same results as a generously provisioned engine."""
    rng = np.random.RandomState(2)
    vecs = rng.randn(80, DIM).astype(np.float32)
    tight = _engine("ivfpq", delta_capacity=64,
                    row_capacity=N + 8, cell_slack=2)
    roomy = _engine("ivfpq", delta_capacity=64,
                    row_capacity=N + 512, cell_slack=512)
    for eng in (tight, roomy):
        eng.upsert(np.arange(N, N + 80), vecs)
        eng.compact()
    assert tight.grow_count >= 1 and roomy.grow_count == 0
    q = _queries()
    _, i1 = tight.search(q, K)
    _, i2 = roomy.search(q, K)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_streaming_engine_releases_dense_state():
    """The StreamStore owns fresh copies of every database leaf, so the
    dense EngineState duplicates are released at init (no standing 2x);
    frozen quantizers and the caller's corpus array stay alive."""
    x = _data()
    eng = SearchEngine(x, _cfg("ivfpq"))
    assert eng.state is None
    assert not x.is_deleted()                       # caller-owned
    for leaf in jax.tree_util.tree_leaves(eng.frozen):
        assert not leaf.is_deleted()
    _, ids = eng.search(_queries(4), K)             # still serves
    assert np.asarray(ids).shape == (4, K)


def test_upsert_fn_reports_dropped_on_full_delta():
    """The raw (engine-less) write API signals overflow instead of
    silently losing rows."""
    from repro.search import upsert_fn
    eng = _engine("flat", delta_capacity=4)
    rng = np.random.RandomState(0)
    ids = jnp.arange(N + 100, N + 108, dtype=jnp.int32)
    vecs = jnp.asarray(rng.randn(8, DIM), jnp.float32)
    store, dropped = upsert_fn(eng.store, eng.frozen, ids, vecs)
    assert int(dropped) == 4                        # 4 fit, 4 reported lost
    assert int(store.delta_count) == 4


# --- config / guard rails ----------------------------------------------------

def test_stream_pq_kernel_backend_rejected():
    with pytest.raises(ValueError, match="pq_backend"):
        ServeConfig(index="pq", pq_backend="kernel",
                    stream=StreamConfig())


def test_streamconfig_validation():
    with pytest.raises(ValueError, match="delta_capacity"):
        StreamConfig(delta_capacity=0)
    with pytest.raises(ValueError, match="compact_threshold"):
        StreamConfig(compact_threshold=0.0)
    with pytest.raises(ValueError, match="write_bucket"):
        StreamConfig(write_bucket=0)


def test_streaming_after_shard_rejected():
    """streaming() must come before shard(): the store takes over the
    dense arrays, which would strand (or delete) the placed sharded
    state."""
    eng = SearchEngine(_data(), ServeConfig(target_dim=None))
    eng.shard(jax.make_mesh((1,), ("data",)))
    with pytest.raises(RuntimeError, match="BEFORE shard"):
        eng.streaming(StreamConfig())


def test_write_api_requires_stream_config():
    eng = SearchEngine(_data(), ServeConfig(target_dim=None))
    with pytest.raises(RuntimeError, match="read-only"):
        eng.upsert(np.array([0]), np.zeros((1, DIM), np.float32))
    with pytest.raises(RuntimeError, match="read-only"):
        eng.delete(np.array([0]))
    with pytest.raises(RuntimeError, match="read-only"):
        eng.compact()


def test_ivfpq_kernel_backend_streams():
    """The fused Pallas ADC-gather kernel serves the tombstone-masked scan
    (the mask rides the additive base term)."""
    eng = SearchEngine(_data(), dataclasses.replace(
        _cfg("ivfpq"), pq_backend="kernel"))
    ref = SearchEngine(_data(), _cfg("ivfpq"))
    q = _queries(8)
    rng = np.random.RandomState(3)
    vecs = rng.randn(16, DIM).astype(np.float32)
    for eng_ in (eng, ref):
        eng_.upsert(np.arange(N, N + 16), vecs)
        eng_.delete(np.arange(0, 20, 2))
    _, i1 = eng.search(q, K)
    _, i2 = ref.search(q, K)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
