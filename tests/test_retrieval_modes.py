"""Two-tower retrieval serving modes: full vs MPAD-reduced vs int8-reduced
(the §Perf hillclimb cell) — recall parity through the exact re-rank."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MPADConfig, fit_mpad
from repro.models.recsys import (TwoTowerConfig, quantize_candidates,
                                 twotower_init, twotower_item,
                                 twotower_retrieve)


def _setup():
    cfg = TwoTowerConfig(name="t", n_users=300, n_items=400, n_negatives=8)
    p = twotower_init(jax.random.key(0), cfg)
    cand = twotower_item(p, cfg, jnp.arange(cfg.n_items))
    red = fit_mpad(cand, MPADConfig(m=32, iters=32))
    batch = {"user_ids": jnp.arange(1),
             "hist_ids": jnp.arange(8)[None, :]}
    return cfg, p, cand, red, batch


def test_modes_agree_through_rerank():
    cfg, p, cand, red, batch = _setup()
    cr = (cand - red.mean) @ red.matrix.T
    cq, scale = quantize_candidates(cr)
    b_full = dict(batch, cand_emb=cand)
    b_mpad = dict(batch, cand_emb=cand, cand_red=cr)
    b_int8 = dict(batch, cand_emb=cand, cand_red_q=cq, cand_scale=scale)
    s0, i0 = twotower_retrieve(p, cfg, b_full, k=10)
    s1, i1 = twotower_retrieve(p, cfg, b_mpad, k=10,
                               reducer=(red.matrix, red.mean), rerank=100)
    s2, i2 = twotower_retrieve(p, cfg, b_int8, k=10,
                               reducer=(red.matrix, red.mean), rerank=100,
                               quantized=True)
    ov1 = len(set(np.asarray(i0).tolist()) & set(np.asarray(i1).tolist()))
    ov2 = len(set(np.asarray(i0).tolist()) & set(np.asarray(i2).tolist()))
    assert ov1 >= 7, ov1          # rerank recovers most of the exact top-10
    assert ov2 >= ov1 - 2, (ov1, ov2)   # int8 costs little extra


def test_quantization_roundtrip():
    x = jax.random.normal(jax.random.key(1), (100, 16)) * 3
    q, s = quantize_candidates(x)
    err = jnp.abs(q.astype(jnp.float32) * s[None, :] - x)
    assert float(jnp.max(err)) <= float(jnp.max(s)) * 0.51 + 1e-6
