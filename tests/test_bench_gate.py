"""The CI bench regression gate (benchmarks/check_regression.py): QPS /
recall thresholds on the gated serving row, and its missing-row policy."""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.check_regression import check, find_row  # noqa: E402


def _doc(qps=8000, recall=0.93, ups=None, stream_recall=0.9,
         bf16_qps=7900, int8_qps=7800, b64_speedup=1.2, sweep=True):
    doc = {"rows": [
        {"index": "ivfpq", "lut_dtype": "int8", "batch": 256,
         "qps": int8_qps, "recall_at_10": 0.92},
        {"index": "ivfpq", "lut_dtype": "bf16", "batch": 256,
         "qps": bf16_qps, "recall_at_10": 0.92},
        {"index": "ivfpq", "lut_dtype": "f32", "batch": 256,
         "qps": qps, "recall_at_10": recall},
    ], "staged_vs_fused": [
        {"index": "ivfpq", "batch": 64, "speedup": b64_speedup},
        {"index": "ivfpq", "batch": 256, "speedup": 3.0},
    ]}
    if sweep:
        doc["batch_sweep"] = [
            {"index": "ivfpq", "batch": b, "qps": 1000} for b in (1, 64)]
    if ups is not None:
        doc["stream"] = [
            {"scenario": "stream_90_10", "index": "ivfpq",
             "upserts_per_sec": ups, "recall_at_10": stream_recall}]
    return doc


def test_find_row_selects_the_gated_cell():
    row = find_row(_doc(), index="ivfpq", lut_dtype="f32", batch=256)
    assert row["qps"] == 8000


def test_gate_passes_within_thresholds():
    failures, _ = check(_doc(), _doc(qps=6500, recall=0.915))
    assert not failures          # -18.75% qps, -0.015 recall: inside limits


def test_gate_fails_on_qps_drop():
    failures, _ = check(_doc(), _doc(qps=6000))          # -25%
    assert any("QPS" in f for f in failures)


def test_gate_fails_on_recall_drop():
    failures, _ = check(_doc(), _doc(recall=0.90))       # -0.03
    assert any("recall" in f for f in failures)


def test_gate_fails_when_fresh_row_missing():
    failures, _ = check(_doc(), {"rows": []})
    assert any("missing" in f for f in failures)


def test_gate_tolerates_missing_baseline_row():
    failures, report = check({"rows": []}, _doc())
    assert not failures and any("skipping" in r for r in report)


# --- streaming (update-throughput) gate --------------------------------------

def test_stream_gate_inactive_without_baseline_rows():
    """Pre-streaming baselines: the stream compare just skips."""
    failures, report = check(_doc(), _doc(ups=5000))
    assert not failures
    assert any("skipping stream" in r for r in report)


def test_stream_gate_passes_within_thresholds():
    failures, _ = check(_doc(ups=5000), _doc(ups=4000))      # -20%
    assert not failures


def test_stream_gate_fails_on_update_throughput_drop():
    failures, _ = check(_doc(ups=5000), _doc(ups=3000))      # -40%
    assert any("update-throughput" in f for f in failures)


def test_stream_gate_fails_on_stream_recall_drop():
    failures, _ = check(_doc(ups=5000), _doc(ups=5000, stream_recall=0.85))
    assert any("streaming recall" in f for f in failures)


def test_stream_gate_fails_when_fresh_rows_vanish():
    failures, _ = check(_doc(ups=5000), _doc())
    assert any("missing the stream row" in f for f in failures)


# --- scan-path gates (within the fresh file) ---------------------------------

def test_lut_parity_gate_passes_at_floor():
    failures, _ = check(_doc(), _doc(bf16_qps=7600, int8_qps=7600))  # 0.95x
    assert not failures


def test_lut_parity_gate_fails_on_slow_quantized_lut():
    failures, _ = check(_doc(), _doc(int8_qps=7000))         # 0.875x < 0.95x
    assert any("quantized-LUT" in f for f in failures)
    failures, _ = check(_doc(), _doc(bf16_qps=7000))
    assert any("quantized-LUT" in f for f in failures)


def test_lut_parity_gate_fails_when_quantized_row_missing():
    fresh = _doc()
    fresh["rows"] = [r for r in fresh["rows"] if r["lut_dtype"] != "bf16"]
    failures, _ = check(_doc(), fresh)
    assert any("bf16" in f and "missing" in f for f in failures)


def test_small_batch_gate_fails_below_parity():
    failures, _ = check(_doc(), _doc(b64_speedup=0.84))      # the old number
    assert any("small-batch regression" in f for f in failures)


def test_small_batch_gate_passes_at_parity():
    failures, _ = check(_doc(), _doc(b64_speedup=1.0))
    assert not failures


def test_batch_sweep_lost_coverage_fails():
    failures, _ = check(_doc(), _doc(sweep=False))
    assert any("batch_sweep" in f for f in failures)
    # a baseline that predates the sweep does not demand it of the fresh run
    failures, _ = check(_doc(sweep=False), _doc(sweep=False))
    assert not failures
