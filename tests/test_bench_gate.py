"""The CI bench regression gate (benchmarks/check_regression.py): QPS /
recall thresholds on the gated serving row, and its missing-row policy."""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.check_regression import check, find_row  # noqa: E402


def _doc(qps=8000, recall=0.93):
    return {"rows": [
        {"index": "ivfpq", "lut_dtype": "int8", "batch": 256,
         "qps": 7000, "recall_at_10": 0.92},
        {"index": "ivfpq", "lut_dtype": "f32", "batch": 256,
         "qps": qps, "recall_at_10": recall},
    ]}


def test_find_row_selects_the_gated_cell():
    row = find_row(_doc(), index="ivfpq", lut_dtype="f32", batch=256)
    assert row["qps"] == 8000


def test_gate_passes_within_thresholds():
    failures, _ = check(_doc(), _doc(qps=6500, recall=0.915))
    assert not failures          # -18.75% qps, -0.015 recall: inside limits


def test_gate_fails_on_qps_drop():
    failures, _ = check(_doc(), _doc(qps=6000))          # -25%
    assert any("QPS" in f for f in failures)


def test_gate_fails_on_recall_drop():
    failures, _ = check(_doc(), _doc(recall=0.90))       # -0.03
    assert any("recall" in f for f in failures)


def test_gate_fails_when_fresh_row_missing():
    failures, _ = check(_doc(), {"rows": []})
    assert any("missing" in f for f in failures)


def test_gate_tolerates_missing_baseline_row():
    failures, report = check({"rows": []}, _doc())
    assert not failures and any("skipping" in r for r in report)
