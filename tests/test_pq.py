"""Product-quantization index tests + the full MPAD->PQ compression stack."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MPADConfig, fit_mpad
from repro.search import knn_search
from repro.search.knn import recall_at_k
from repro.search.pq import build_pq, pq_reconstruct, pq_search


def _data(n=800, d=32, seed=0):
    key = jax.random.key(seed)
    centers = jax.random.normal(key, (16, d)) * 2
    lab = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 16)
    return centers[lab] + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 2), (n, d))


def test_reconstruction_error_decreases_with_m():
    x = _data()
    errs = []
    for m in (2, 4, 8):
        idx = build_pq(jax.random.key(1), x, m_subspaces=m, n_centroids=64)
        rec = pq_reconstruct(idx)
        errs.append(float(jnp.mean((rec - x) ** 2)))
    assert errs[0] > errs[1] > errs[2], errs


def test_pq_search_recall():
    x = _data()
    q = _data(n=64, seed=9)
    idx = build_pq(jax.random.key(1), x, m_subspaces=8, n_centroids=128)
    _, truth = knn_search(q, x, 10)
    _, found = pq_search(idx, q, 10)
    assert float(recall_at_k(found, truth)) > 0.55


def test_mpad_then_pq_stack():
    """The full memory hierarchy: 32-d f32 -> MPAD 16-d -> PQ 4 bytes."""
    x = _data()
    q = _data(n=64, seed=9)
    red = fit_mpad(x, MPADConfig(m=16, iters=40))
    xr, qr = red(x), red(q)
    idx = build_pq(jax.random.key(1), xr, m_subspaces=4, n_centroids=128)
    _, truth = knn_search(q, x, 10)
    _, cand = pq_search(idx, qr, 40)            # over-retrieve
    # exact re-rank of candidates in the original space
    cv = x[cand]
    d2 = jnp.sum((cv - q[:, None, :]) ** 2, -1)
    _, sel = jax.lax.top_k(-d2, 10)
    found = jnp.take_along_axis(cand, sel, axis=1)
    rec = float(recall_at_k(found, truth))
    assert rec > 0.75, rec                      # 32x compression, rerank fixes
