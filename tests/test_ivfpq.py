"""IVF-PQ residual index tests: build invariants, residual-coding recall
advantage over plain PQ, backend agreement, the ServeConfig index spec, and
the engine end-to-end path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import make_clustered
from repro.search import (SearchEngine, ServeConfig, build_ivfpq, build_pq,
                          ivfpq_search, knn_search, pq_search)
from repro.search.knn import recall_at_k


def _corpus(n=2000, nq=64, d=64, seed=0):
    return make_clustered(jax.random.key(seed), n, nq, d, n_clusters=24,
                          spread=0.35, center_scale=1.5)


def test_build_layout_invariants():
    x, _ = _corpus(n=500, d=32)
    idx = build_ivfpq(jax.random.key(1), x, nlist=8, m_subspaces=4,
                      n_centroids=32)
    nlist, max_cell = idx.lists.shape
    assert nlist == 8
    ids = np.asarray(idx.lists)
    valid = ids[ids >= 0]
    # every vector appears exactly once across the posting lists
    np.testing.assert_array_equal(np.sort(valid), np.arange(x.shape[0]))
    assert idx.codes.shape == (x.shape[0], 4)
    assert idx.bias.shape == (x.shape[0],)
    assert int(idx.codes.min()) >= 0 and int(idx.codes.max()) < 32


def test_full_probe_matches_reconstruction_distance():
    """With every cell probed, ivfpq distances must equal the exact L2
    distance to the PQ reconstruction (centroid + decoded residual) — the
    decomposition in ivfpq.py is exact, not an approximation."""
    x, q = _corpus(n=400, nq=16, d=32)
    idx = build_ivfpq(jax.random.key(1), x, nlist=4, m_subspaces=4,
                      n_centroids=32)
    d_found, ids = ivfpq_search(idx, q, 5, nprobe=4)
    # reconstruct the corpus: assigned centroid + decoded residual
    cent, lists = np.asarray(idx.centroids), np.asarray(idx.lists)
    cell_of = np.empty(x.shape[0], np.int64)
    for c in range(lists.shape[0]):
        members = lists[c][lists[c] >= 0]
        cell_of[members] = c
    cbs, codes = np.asarray(idx.codebooks), np.asarray(idx.codes)
    m, _, dsub = cbs.shape
    recon = cent[cell_of] + np.concatenate(
        [cbs[j][codes[:, j]] for j in range(m)], axis=1)
    d_exact = np.linalg.norm(
        recon[np.asarray(ids)] - np.asarray(q)[:, None, :], axis=-1)
    np.testing.assert_allclose(np.asarray(d_found), d_exact, atol=1e-3)


def test_ivfpq_recall_at_least_pq_at_equal_budget():
    """Residual coding spends the same code bytes on much smaller vectors,
    so full-probe IVF-PQ recall must be >= plain PQ recall."""
    x, q = _corpus()
    _, truth = knn_search(q, x, 10)
    m, kc = 8, 64
    ivfpq = build_ivfpq(jax.random.key(1), x, nlist=16, m_subspaces=m,
                        n_centroids=kc)
    pq = build_pq(jax.random.key(1), x, m_subspaces=m, n_centroids=kc)
    _, found_i = ivfpq_search(ivfpq, q, 10, nprobe=16)
    _, found_p = pq_search(pq, q, 10)
    rec_i = float(recall_at_k(found_i, truth))
    rec_p = float(recall_at_k(found_p, truth))
    assert rec_i >= rec_p, (rec_i, rec_p)


def test_partial_probe_reasonable():
    x, q = _corpus()
    _, truth = knn_search(q, x, 10)
    idx = build_ivfpq(jax.random.key(1), x, nlist=16, m_subspaces=8,
                      n_centroids=64)
    _, full = ivfpq_search(idx, q, 10, nprobe=16)
    _, part = ivfpq_search(idx, q, 10, nprobe=4)
    rec_full = float(recall_at_k(full, truth))
    rec_part = float(recall_at_k(part, truth))
    assert rec_part > 0.5 * rec_full, (rec_part, rec_full)


def test_backend_kernel_matches_jnp():
    x, q = _corpus(n=800, nq=32)
    idx = build_ivfpq(jax.random.key(1), x, nlist=8, m_subspaces=8,
                      n_centroids=64)
    d_j, _ = ivfpq_search(idx, q, 10, nprobe=4, backend="jnp")
    d_k, _ = ivfpq_search(idx, q, 10, nprobe=4, backend="kernel")
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_j), atol=1e-4)


def test_engine_ivfpq_end_to_end_recall():
    """reduce -> coarse-probe -> residual ADC -> exact re-rank >= 0.9."""
    x, q = _corpus(n=4000, nq=64, d=64, seed=7)
    _, truth = knn_search(q, x, 10)
    eng = SearchEngine(x, ServeConfig(
        target_dim=None, rerank=64, index="ivfpq", nlist=32, nprobe=16,
        pq_subspaces=8, pq_centroids=128))
    _, found = eng.search(q, 10)
    rec = float(recall_at_k(found, truth))
    assert rec >= 0.9, rec


def test_engine_ivfpq_kernel_backend():
    x, q = _corpus(n=1000, nq=32)
    _, truth = knn_search(q, x, 10)
    eng = SearchEngine(x, ServeConfig(
        target_dim=None, rerank=48, index="ivfpq", nlist=16, nprobe=8,
        pq_subspaces=8, pq_centroids=64, pq_backend="kernel"))
    _, found = eng.search(q, 10)
    assert float(recall_at_k(found, truth)) > 0.7


# --- quantized LUT path ------------------------------------------------------

@pytest.mark.parametrize("lut_dtype", ["bf16", "int8"])
def test_ivfpq_backends_agree_per_lut_dtype(lut_dtype):
    x, q = _corpus(n=800, nq=32)
    idx = build_ivfpq(jax.random.key(1), x, nlist=8, m_subspaces=8,
                      n_centroids=64)
    d_j, _ = ivfpq_search(idx, q, 10, nprobe=4, lut_dtype=lut_dtype)
    d_k, _ = ivfpq_search(idx, q, 10, nprobe=4, backend="kernel",
                          lut_dtype=lut_dtype)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_j), atol=1e-3)


def test_engine_ivfpq_int8_lut_recall_floor():
    """End-to-end acceptance: ivfpq + lut_dtype="int8" + exact re-rank must
    hold recall@10 within 0.01 of the f32 LUT path — the re-rank absorbs the
    table rounding as long as the true neighbors stay in the candidate set."""
    x, q = _corpus(n=4000, nq=64, d=64, seed=7)
    _, truth = knn_search(q, x, 10)
    recs = {}
    for lut in ("f32", "int8"):
        eng = SearchEngine(x, ServeConfig(
            target_dim=None, rerank=64, index="ivfpq", nlist=32, nprobe=16,
            pq_subspaces=8, pq_centroids=128, lut_dtype=lut))
        _, found = eng.search(q, 10)
        recs[lut] = float(recall_at_k(found, truth))
    assert recs["int8"] >= recs["f32"] - 0.01, recs
    assert recs["f32"] >= 0.9, recs


# --- ServeConfig index spec ------------------------------------------------

def test_serveconfig_rejects_unknown_index():
    with pytest.raises(ValueError, match="index kind"):
        ServeConfig(index="hnsw")
    with pytest.raises(ValueError, match="pq_backend"):
        ServeConfig(pq_backend="triton")


def test_serveconfig_boolean_shim_removed():
    """PR-1 deprecation cycle complete: the use_ivf/use_pq booleans now
    raise with a pointer to the spec grammar — even explicit False (the
    parameter itself is gone, not just the True path)."""
    for kw in (dict(use_ivf=True), dict(use_pq=True),
               dict(use_ivf=True, use_pq=True), dict(use_ivf=False),
               dict(index="ivf", use_pq=True)):
        with pytest.raises(ValueError, match="spec"):
            ServeConfig(**kw)


def test_serveconfig_rejects_dead_knobs():
    """Knobs whose stage is absent from the selected pipeline are rejected
    instead of silently ignored (the old nlist-under-pq trap)."""
    with pytest.raises(ValueError, match="dead knob"):
        ServeConfig(index="pq", nlist=128)
    with pytest.raises(ValueError, match="dead knob"):
        ServeConfig(index="flat", nprobe=4)
    with pytest.raises(ValueError, match="dead knob"):
        ServeConfig(index="ivf", lut_dtype="int8")
    # defaults are not a selection: all-default knobs pass for every kind
    for kind in ("flat", "ivf", "pq", "ivfpq"):
        ServeConfig(index=kind)


def test_serveconfig_rejects_nprobe_above_nlist():
    with pytest.raises(ValueError, match="nprobe exceeds nlist"):
        ServeConfig(index="ivf", nlist=8, nprobe=16)


# --- degenerate probe budgets -----------------------------------------------

def test_small_probe_budget_pads_instead_of_crashing():
    """nprobe*max_cell < k must yield -1/inf padding, not a trace error."""
    x = jax.random.normal(jax.random.key(0), (24, 16))
    idx = build_ivfpq(jax.random.key(1), x, nlist=8, m_subspaces=4,
                      n_centroids=16)
    k = idx.lists.shape[1] + 5                      # k > one cell's capacity
    d, i = ivfpq_search(idx, x[:3], k, nprobe=1)
    assert d.shape == (3, k) and i.shape == (3, k)
    pad = np.asarray(i) < 0
    assert np.isinf(np.asarray(d)[pad]).all()       # pads carry inf distance


def test_kernel_backend_unfilled_slots_stay_minus_one():
    """When finite candidates < k, the kernel's sel=-1 slots must surface as
    id -1 (like the jnp backend), not wrap-around duplicates of real ids."""
    x = jax.random.normal(jax.random.key(5), (24, 16))
    idx = build_ivfpq(jax.random.key(6), x, nlist=8, m_subspaces=4,
                      n_centroids=16)
    k = idx.lists.shape[1] + 5
    d_j, i_j = ivfpq_search(idx, x[:3], k, nprobe=1, backend="jnp")
    d_k, i_k = ivfpq_search(idx, x[:3], k, nprobe=1, backend="kernel")
    i_j, i_k = np.asarray(i_j), np.asarray(i_k)
    np.testing.assert_array_equal(i_j < 0, i_k < 0)
    for row_j, row_k in zip(i_j, i_k):
        np.testing.assert_array_equal(np.sort(row_j[row_j >= 0]),
                                      np.sort(row_k[row_k >= 0]))
        real = row_k[row_k >= 0]
        assert len(set(real.tolist())) == len(real)      # no duplicates


def test_ivf_small_probe_budget_pads_instead_of_crashing():
    from repro.search import build_ivf, ivf_search
    x = jax.random.normal(jax.random.key(0), (24, 16))
    idx = build_ivf(jax.random.key(1), x, nlist=8)
    k = idx.lists.shape[1] + 5
    d, i = ivf_search(idx, x[:3], k, nprobe=1)
    assert d.shape == (3, k)
    assert np.isinf(np.asarray(d)[np.asarray(i) < 0]).all()


def test_rerank_never_promotes_pad_ids():
    """Under-filled probes: -1 pads must not displace real candidates in the
    engine's exact re-rank (they used to negative-index corpus[-1])."""
    x = jax.random.normal(jax.random.key(2), (64, 16))
    eng = SearchEngine(x, ServeConfig(index="ivfpq", nlist=16, nprobe=1,
                                      pq_subspaces=4, pq_centroids=16,
                                      rerank=4))
    d, ids = eng.search(x[:8], 3)
    ids, d = np.asarray(ids), np.asarray(d)
    # any pad that survives must rank strictly after every real candidate
    assert (np.isinf(d[ids < 0])).all()
    assert np.isfinite(d[ids >= 0]).all()
